//! Batched-vs-sequential equivalence suite — the central invariant of the
//! continuous-batching subsystem: fusing live sessions into one batched
//! decode call per round commits **byte-identical token streams** to
//! driving each session with per-session calls.
//!
//! Runs against the simulated artifacts
//! (`lookahead::runtime::sim::write_sim_artifacts` + the vendored xla
//! stub's deterministic LM), so the whole path — runtime, engines,
//! `step_group`, `BatchedRound` serving — executes for real without PJRT.
//!
//! Claims pinned here:
//!   1. For autoregressive and lookahead engines, batch sizes 1/2/5 with
//!      mixed prompt lengths: identical tokens, identical
//!      `DecodeStats.generated_tokens` / `decode_steps`, identical
//!      per-step delta sequences (private pools).
//!   2. Works under sampling (per-session RNG state is batch-invariant).
//!   3. Mixed-engine groups fuse per group key and stay correct.
//!   4. Jacobi and spec_decode groups (the `BatchStep` plan/finish split)
//!      stay byte-identical through `step_group` — on sim artifacts they
//!      never fuse (no batched lin-k executables), so this pins the
//!      grouped-fallback path.
//!   5. A `ServerHandle` with `batch_decode` on serves the same streams
//!      (chunk deltas + final records) as one with it off, and reports
//!      `batched_rounds` / `batch_size` metrics.
//!   6. Property: random open/cancel interleavings never leak tokens
//!      across sessions and always end in well-formed final records.

use std::collections::HashMap;

use lookahead::engine::autoregressive::AutoRegressive;
use lookahead::engine::jacobi::Jacobi;
use lookahead::engine::lookahead::Lookahead;
use lookahead::engine::spec_decode::SpecDecode;
use lookahead::engine::{step_group, Decoder, DecodeSession, GenParams, SamplingParams,
                        StepOutcome};
use lookahead::ngram::PoolHandle;
use lookahead::runtime::sim::{ensure_sim_artifacts, ensure_slow_sim_artifacts};
use lookahead::runtime::{cpu_client, Manifest, ModelRuntime};
use lookahead::server::{Reply, Request, Response, ServerConfig, ServerHandle};
use lookahead::tokenizer::ByteTokenizer;
use lookahead::util::prop::forall;
use lookahead::util::rng::Rng;

fn sim_dir() -> String {
    ensure_sim_artifacts().unwrap().to_string_lossy().into_owned()
}

fn setup() -> ModelRuntime {
    let manifest = Manifest::load(sim_dir()).unwrap();
    let client = cpu_client().unwrap();
    ModelRuntime::load(&client, &manifest, "tiny").unwrap()
}

const PROMPTS: [&str; 5] = [
    "def add_ab(a, b):\n    result = a",
    "Q: 12 + 34?\n",
    "the quick brown fox jumps over",
    "x",
    "lorem ipsum dolor sit amet, consectetur",
];

fn prompt_ids(n: usize) -> Vec<Vec<u32>> {
    let tok = ByteTokenizer::new();
    PROMPTS.iter().cycle().take(n).map(|t| tok.encode_with_bos(t)).collect()
}

/// Everything a run commits, step-structured.
#[derive(Debug, PartialEq)]
struct RunLog {
    tokens: Vec<u32>,
    deltas: Vec<Vec<u32>>,
    generated: usize,
    steps: usize,
}

fn run_sequential(engine: &dyn Decoder, rt: &ModelRuntime, prompt: &[u32],
                  params: &GenParams) -> RunLog {
    let pool = PoolHandle::for_spec(engine.pool_spec());
    let mut sess = engine.begin(rt, prompt, params, pool).unwrap();
    let mut deltas = Vec::new();
    loop {
        match sess.step().unwrap() {
            // skip empty commits (an EOS-first step trims to nothing) so
            // delta logs match what the streaming layer would emit
            StepOutcome::Committed { tokens } if !tokens.is_empty() => {
                deltas.push(tokens)
            }
            StepOutcome::Committed { .. } => {}
            StepOutcome::Finished { .. } => break,
        }
    }
    let (out, _) = sess.into_output();
    RunLog {
        tokens: out.tokens,
        deltas,
        generated: out.stats.generated_tokens,
        steps: out.stats.decode_steps,
    }
}

/// Drive a set of already-opened sessions to completion through
/// `step_group` (one fused round per iteration). Returns per-session logs
/// plus the sizes of every fused call issued.
fn drain_group(rt: &ModelRuntime, mut sessions: Vec<Box<dyn DecodeSession + '_>>)
               -> (Vec<RunLog>, Vec<usize>) {
    let n = sessions.len();
    let mut deltas: Vec<Vec<Vec<u32>>> = vec![Vec::new(); n];
    let mut fused_sizes: Vec<usize> = Vec::new();
    loop {
        let active: Vec<usize> =
            (0..n).filter(|&i| sessions[i].finished().is_none()).collect();
        if active.is_empty() {
            break;
        }
        let mut refs: Vec<&mut (dyn DecodeSession + '_)> = sessions
            .iter_mut()
            .enumerate()
            .filter(|(i, _)| active.contains(i))
            .map(|(_, s)| s.as_mut())
            .collect();
        let out = step_group(rt, &mut refs);
        drop(refs);
        fused_sizes.extend(out.fused);
        for (k, res) in out.outcomes.into_iter().enumerate() {
            if let StepOutcome::Committed { tokens } = res.unwrap() {
                if !tokens.is_empty() {
                    deltas[active[k]].push(tokens);
                }
            }
        }
    }
    let logs = sessions
        .into_iter()
        .zip(deltas)
        .map(|(s, d)| {
            let (out, _) = s.into_output();
            RunLog {
                tokens: out.tokens,
                deltas: d,
                generated: out.stats.generated_tokens,
                steps: out.stats.decode_steps,
            }
        })
        .collect();
    (logs, fused_sizes)
}

fn run_batched(engine: &dyn Decoder, rt: &ModelRuntime, prompts: &[Vec<u32>],
               params: &GenParams) -> (Vec<RunLog>, Vec<usize>) {
    let sessions: Vec<Box<dyn DecodeSession + '_>> = prompts
        .iter()
        .map(|p| {
            engine
                .begin(rt, p, params, PoolHandle::for_spec(engine.pool_spec()))
                .unwrap()
        })
        .collect();
    drain_group(rt, sessions)
}

#[test]
fn batched_matches_sequential_at_batch_1_2_5() {
    let rt = setup();
    let engines: Vec<Box<dyn Decoder>> =
        vec![Box::new(AutoRegressive::new()), Box::new(Lookahead::with_wng(5, 3, 5))];
    let params = GenParams { max_new_tokens: 32, ..Default::default() };
    for engine in &engines {
        for batch in [1usize, 2, 5] {
            let prompts = prompt_ids(batch);
            let seq: Vec<RunLog> = prompts
                .iter()
                .map(|p| run_sequential(engine.as_ref(), &rt, p, &params))
                .collect();
            let (bat, fused) = run_batched(engine.as_ref(), &rt, &prompts, &params);
            if batch == 1 {
                // singleton groups take the per-session executable (a padded
                // fused launch would waste bandwidth for identical bytes)
                assert!(fused.is_empty(),
                        "{}: singleton group must not fuse", engine.name());
            } else {
                assert!(!fused.is_empty(), "{}: batch {batch} issued no fused calls",
                        engine.name());
                assert!(fused.iter().all(|&s| (2..=batch).contains(&s)));
            }
            for (i, (s, b)) in seq.iter().zip(&bat).enumerate() {
                assert_eq!(s.tokens, b.tokens,
                           "{}: batch {batch} session {i}: tokens diverged",
                           engine.name());
                assert_eq!(s.deltas, b.deltas,
                           "{}: batch {batch} session {i}: step deltas diverged",
                           engine.name());
                assert_eq!(s.generated, b.generated,
                           "{}: batch {batch} session {i}: generated_tokens diverged",
                           engine.name());
                assert_eq!(s.steps, b.steps,
                           "{}: batch {batch} session {i}: decode_steps diverged",
                           engine.name());
            }
            // the suite must exercise real decoding, not 5 EOS-first stubs
            // (one prompt intentionally EOSes immediately — the empty-stream
            // edge case — but not all of them)
            assert!(seq.iter().map(|l| l.tokens.len()).sum::<usize>() > 0,
                    "{}: batch {batch}: every run was empty", engine.name());
        }
    }
}

#[test]
fn jacobi_and_spec_groups_match_sequential_without_fusing() {
    let rt = setup();
    let manifest = Manifest::load(sim_dir()).unwrap();
    let params = GenParams { max_new_tokens: 24, ..Default::default() };
    let engines: Vec<Box<dyn Decoder>> = vec![
        Box::new(Jacobi::new(8)),
        Box::new(SpecDecode::new(
            ModelRuntime::load(&rt.client, &manifest, "draft").unwrap(),
            4,
        )),
    ];
    for engine in &engines {
        for batch in [2usize, 3] {
            let prompts = prompt_ids(batch);
            let seq: Vec<RunLog> = prompts
                .iter()
                .map(|p| run_sequential(engine.as_ref(), &rt, p, &params))
                .collect();
            let (bat, fused) = run_batched(engine.as_ref(), &rt, &prompts, &params);
            // sim artifacts carry batched executables only for the AR and
            // generic-lookahead shapes, so these groups plan together and
            // then take the per-session fallback — zero fused launches
            assert!(fused.is_empty(),
                    "{}: sim must not fuse lin-k groups, got {fused:?}",
                    engine.name());
            for (i, (s, b)) in seq.iter().zip(&bat).enumerate() {
                assert_eq!(s.tokens, b.tokens,
                           "{}: batch {batch} session {i}: tokens diverged",
                           engine.name());
                assert_eq!(s.deltas, b.deltas,
                           "{}: batch {batch} session {i}: step deltas diverged",
                           engine.name());
                assert_eq!(s.generated, b.generated,
                           "{}: batch {batch} session {i}: generated_tokens diverged",
                           engine.name());
                assert_eq!(s.steps, b.steps,
                           "{}: batch {batch} session {i}: decode_steps diverged",
                           engine.name());
            }
            assert!(seq.iter().map(|l| l.tokens.len()).sum::<usize>() > 0,
                    "{}: batch {batch}: every run was empty", engine.name());
        }
    }
}

#[test]
fn batched_matches_sequential_under_sampling() {
    let rt = setup();
    let engine = AutoRegressive::new();
    let params = GenParams {
        max_new_tokens: 24,
        sampling: SamplingParams { temperature: 0.8, top_k: 40, top_p: 0.95 },
        stop_at_eos: true,
        seed: 7,
    };
    let prompts = prompt_ids(3);
    let seq: Vec<RunLog> =
        prompts.iter().map(|p| run_sequential(&engine, &rt, p, &params)).collect();
    let (bat, _) = run_batched(&engine, &rt, &prompts, &params);
    for (s, b) in seq.iter().zip(&bat) {
        assert_eq!(s.tokens, b.tokens, "sampled batched run diverged");
        assert_eq!(s.deltas, b.deltas);
    }
}

#[test]
fn mixed_engine_group_fuses_per_key_and_stays_correct() {
    let rt = setup();
    let ar = AutoRegressive::new();
    let la = Lookahead::with_wng(5, 3, 5);
    let params = GenParams { max_new_tokens: 24, ..Default::default() };
    let prompts = prompt_ids(4);

    let seq: Vec<RunLog> = vec![
        run_sequential(&ar, &rt, &prompts[0], &params),
        run_sequential(&la, &rt, &prompts[1], &params),
        run_sequential(&ar, &rt, &prompts[2], &params),
        run_sequential(&la, &rt, &prompts[3], &params),
    ];

    let sessions: Vec<Box<dyn DecodeSession + '_>> = vec![
        ar.begin(&rt, &prompts[0], &params, PoolHandle::none()).unwrap(),
        la.begin(&rt, &prompts[1], &params, PoolHandle::for_spec(la.pool_spec()))
            .unwrap(),
        ar.begin(&rt, &prompts[2], &params, PoolHandle::none()).unwrap(),
        la.begin(&rt, &prompts[3], &params, PoolHandle::for_spec(la.pool_spec()))
            .unwrap(),
    ];
    let (bat, fused) = drain_group(&rt, sessions);
    // two engines -> two fused calls per round while all four run
    assert!(fused.iter().any(|&s| s == 2), "expected fused pairs, got {fused:?}");
    for (i, (s, b)) in seq.iter().zip(&bat).enumerate() {
        assert_eq!(s.tokens, b.tokens, "mixed group session {i} diverged");
        assert_eq!(s.deltas, b.deltas, "mixed group session {i} deltas diverged");
    }
}

// ---------------------------------------------------------------------------
// serving-layer equivalence: BatchedRound vs sequential drive
// ---------------------------------------------------------------------------

fn server_cfg(artifacts: String, batch: bool, max_live: usize, time_slice: usize)
              -> ServerConfig {
    // private pools: each session's stream is then a pure function of
    // its own request, so streams are invariant to batching AND to
    // admission timing (shared pools keep bytes identical but may move
    // step boundaries — see DESIGN.md §3c)
    ServerConfig::builder()
        .queue_depth(64)
        .share_ngrams(false)
        .batch_decode(batch)
        .artifacts_dir(artifacts)
        .time_slice(time_slice)
        .max_live(max_live)
        .build()
}

/// Slow-decode sim artifacts (identical token streams, ~5ms per decode
/// launch): submissions land well inside request 1's first steps, so the
/// batched server demonstrably groups sessions.
fn slow_dir() -> String {
    ensure_slow_sim_artifacts().unwrap().to_string_lossy().into_owned()
}

fn requests() -> Vec<Request> {
    PROMPTS
        .iter()
        .enumerate()
        .map(|(i, p)| {
            Request::new(*p)
                .max_tokens(24 + 4 * i)
                .method(if i % 2 == 0 { "autoregressive" } else { "lookahead" })
                .stream(true)
        })
        .collect()
}

/// Submit `reqs` and collect (chunk deltas, final record) per request.
fn serve_all(h: &ServerHandle, reqs: Vec<Request>) -> Vec<(Vec<String>, Response)> {
    let streams: Vec<_> = reqs.into_iter().map(|r| h.submit(r).unwrap()).collect();
    streams
        .into_iter()
        .map(|rs| {
            let mut deltas = Vec::new();
            loop {
                match rs.recv().unwrap() {
                    Reply::Chunk(c) => {
                        assert_eq!(c.id, rs.id, "chunk routed to the wrong stream");
                        deltas.push(c.delta);
                    }
                    Reply::Done(resp) => {
                        assert_eq!(resp.id, rs.id);
                        return (deltas, resp);
                    }
                }
            }
        })
        .collect()
}

#[test]
fn server_batched_serving_matches_sequential_serving() {
    let h_seq = ServerHandle::start(server_cfg(slow_dir(), false, 5, 2)).unwrap();
    let seq = serve_all(&h_seq, requests());
    h_seq.shutdown();

    let h_bat = ServerHandle::start(server_cfg(slow_dir(), true, 5, 2)).unwrap();
    let bat = serve_all(&h_bat, requests());

    for (i, ((sd, sr), (bd, br))) in seq.iter().zip(&bat).enumerate() {
        assert!(sr.error.is_none() && br.error.is_none(), "request {i} errored");
        assert_eq!(sr.text, br.text, "request {i}: final text diverged");
        assert_eq!(sr.tokens, br.tokens, "request {i}: token count diverged");
        assert_eq!(sr.finish, br.finish, "request {i}: finish reason diverged");
        assert_eq!(sd, bd, "request {i}: streaming delta sequence diverged");
        assert_eq!(sd.concat(), sr.text, "request {i}: deltas must rebuild text");
    }

    // the batched server must actually have fused rounds, and say so
    {
        let mut m = h_bat.metrics.lock();
        assert!(m.counter("batched_rounds") > 0,
                "batch_decode server never fused a round");
        let sizes = m.histograms.get_mut("batch_size").expect("batch_size histogram");
        assert!(sizes.max() >= 2.0, "fused rounds never reached batch >= 2");
    }
    h_bat.shutdown();
}

// ---------------------------------------------------------------------------
// property: random open/cancel interleavings across batched rounds
// ---------------------------------------------------------------------------

#[test]
fn prop_random_interleave_never_crosses_sessions() {
    // instant decodes: cancels usually land after natural completion (the
    // reference-equality oracle) and occasionally mid-run (the partial
    // path, deterministically covered by rust/tests/streaming.rs)
    let h = ServerHandle::start(server_cfg(sim_dir(), true, 4, 1)).unwrap();
    let rt = setup();
    let tok = ByteTokenizer::new();
    // solo reference outputs, computed on demand per (prompt, method, max)
    let mut refs: HashMap<(usize, usize, usize), String> = HashMap::new();
    let mut reference = |pi: usize, mi: usize, max: usize| -> String {
        refs.entry((pi, mi, max))
            .or_insert_with(|| {
                let params = GenParams { max_new_tokens: max, ..Default::default() };
                let ids = tok.encode_with_bos(PROMPTS[pi]);
                let out;
                if mi == 0 {
                    let mut e = AutoRegressive::new();
                    out = e.generate(&rt, &ids, &params);
                } else {
                    let mut e = Lookahead::with_wng(5, 3, 5);
                    out = e.generate(&rt, &ids, &params);
                }
                out.unwrap().text
            })
            .clone()
    };

    forall(
        10,
        0xBA7C4,
        |r: &mut Rng| -> Vec<(usize, usize, usize)> {
            let n = r.range(2, 6);
            (0..n)
                .map(|_| {
                    // (prompt index, max_tokens, cancel-after-k-chunks; 0 = run
                    // to completion)
                    (r.below(PROMPTS.len()), r.range(4, 40), r.below(4))
                })
                .collect()
        },
        |script| {
            let streams: Vec<_> = script
                .iter()
                .map(|&(pi, max, _)| {
                    h.submit(
                        Request::new(PROMPTS[pi])
                            .max_tokens(max)
                            .method(if pi % 2 == 0 {
                                "autoregressive"
                            } else {
                                "lookahead"
                            })
                            .stream(true),
                    )
                    .map_err(|e| e.to_string())
                })
                .collect::<Result<_, _>>()?;
            for (rs, &(pi, max, cancel_after)) in streams.iter().zip(script.iter()) {
                let mut deltas = String::new();
                let mut chunks = 0usize;
                let mut last_seq = 0u64;
                let done = loop {
                    match rs.recv().map_err(|e| e.to_string())? {
                        Reply::Chunk(c) => {
                            if c.id != rs.id {
                                return Err(format!("chunk id {} on stream {}", c.id,
                                                   rs.id));
                            }
                            if c.seq <= last_seq {
                                return Err("chunk seq not increasing".into());
                            }
                            last_seq = c.seq;
                            chunks += 1;
                            deltas.push_str(&c.delta);
                            if cancel_after > 0 && chunks == cancel_after {
                                h.cancel(rs.id);
                            }
                        }
                        Reply::Done(resp) => break resp,
                    }
                };
                if done.id != rs.id {
                    return Err("final record routed to the wrong stream".into());
                }
                if let Some(e) = &done.error {
                    return Err(format!("request errored: {e}"));
                }
                if done.finish.is_empty() {
                    return Err("final record missing finish reason".into());
                }
                if deltas != done.text {
                    return Err(format!(
                        "deltas do not rebuild final text ({} vs {} bytes)",
                        deltas.len(), done.text.len()));
                }
                if done.tokens > max {
                    return Err("budget exceeded".into());
                }
                // completed requests must be byte-identical to a solo run of
                // the same request — the strongest no-cross-talk oracle
                if done.finish == "eos" || done.finish == "budget" {
                    let want = reference(pi, pi % 2, max);
                    if done.text != want {
                        return Err(format!(
                            "completed text diverged from solo reference \
                             (prompt {pi}, max {max})"));
                    }
                }
            }
            Ok(())
        },
    );
    h.shutdown();
}
