//! Shared n-gram cache integration: concurrent insert/lookup under load
//! (no deadlock, caps respected), warm-vs-cold accept length on a repeated
//! prompt through the real runtime, and the scheduler+worker share-toggle.
//!
//! Runtime-dependent tests gate on `artifacts/manifest.json` and skip when
//! the AOT artifacts are absent (CI runs without PJRT).

use std::sync::Arc;

use lookahead::engine::lookahead::Lookahead;
use lookahead::engine::{Decoder, GenParams};
use lookahead::ngram::{NgramCacheRegistry, PoolHandle, PoolSpec, SharedNgramCache};
use lookahead::runtime::load_model;
use lookahead::server::{Request, ServerConfig, ServerHandle};
use lookahead::tokenizer::ByteTokenizer;

/// Skip (returning true) when the AOT artifacts are not built.
fn no_artifacts() -> bool {
    lookahead::bench::skip_without_artifacts(module_path!())
}

#[test]
fn concurrent_insert_lookup_caps_and_counters() {
    let spec = PoolSpec::new(4, 6, 512);
    let cache = Arc::new(SharedNgramCache::new(spec, 8));
    let threads = 8;
    let ops = 5_000u32;
    let mut joins = Vec::new();
    for t in 0..threads as u32 {
        let cache = cache.clone();
        joins.push(std::thread::spawn(move || {
            let mut handle = PoolHandle::shared(cache);
            let mut local_lookups = 0usize;
            for i in 0..ops {
                let k = (i * 7 + t * 131) % 251;
                handle.insert(&[k, i % 23, (i + t) % 19, i % 11]);
                if i % 3 == 0 {
                    let got = handle.lookup(i % 251, 4);
                    assert!(got.len() <= 4, "lookup exceeded max");
                    for s in got {
                        assert_eq!(s.len(), 3, "suffix length must be n-1");
                    }
                    local_lookups += 1;
                }
            }
            assert_eq!(handle.hits + handle.misses, local_lookups);
        }));
    }
    for j in joins {
        j.join().unwrap(); // no deadlock: all threads drain
    }
    let st = cache.stats();
    assert_eq!(st.inserts, (threads as u64) * ops as u64);
    assert!(cache.len() <= 512, "global cap violated: {}", cache.len());
    assert_eq!(st.entries, cache.len());
    // heavy over-insertion must have evicted
    assert!(st.evictions > 0);
}

#[test]
fn registry_is_race_free_across_threads() {
    let reg = Arc::new(NgramCacheRegistry::new());
    let spec = PoolSpec::new(3, 4, 64);
    let mut joins = Vec::new();
    for _ in 0..8 {
        let reg = reg.clone();
        joins.push(std::thread::spawn(move || reg.get_or_create("tiny", spec)));
    }
    let caches: Vec<Arc<SharedNgramCache>> =
        joins.into_iter().map(|j| j.join().unwrap()).collect();
    for c in &caches[1..] {
        assert!(Arc::ptr_eq(&caches[0], c), "racing workers must get one cache");
    }
}

#[test]
fn cross_thread_warmth_via_handles() {
    let cache = Arc::new(SharedNgramCache::with_defaults(PoolSpec::new(3, 4, 256)));
    let c = cache.clone();
    std::thread::spawn(move || {
        let mut h = PoolHandle::shared(c);
        h.seed_from(&[1, 2, 3, 4, 5]);
    })
    .join()
    .unwrap();
    let mut h = PoolHandle::shared(cache);
    assert!(h.warm_start(), "second request must see first request's n-grams");
    assert_eq!(h.lookup(1, 4), vec![vec![2, 3]]);
}

#[test]
fn warm_cache_raises_accept_length_on_repeated_prompt() {
    if no_artifacts() {
        return;
    }
    let (_, rt) = load_model("artifacts", "tiny").unwrap();
    let tok = ByteTokenizer::new();
    let prompt = tok.encode_with_bos(
        "def add_ab(a, b):\n    result = a + b\n    return result\n\ndef add_xy(x, y):\n    result = x");
    let params = GenParams { max_new_tokens: 48, ..Default::default() };
    let mut e = Lookahead::with_wng(5, 3, 5);

    // cold: per-request private pool (the paper's setting)
    let cold = e.generate(&rt, &prompt, &params).unwrap();

    // warm: same repeated prompt through one shared cache
    let cache = Arc::new(SharedNgramCache::with_defaults(e.pool_spec().unwrap()));
    let mut h1 = PoolHandle::shared(cache.clone());
    let first = e.generate_with_pool(&rt, &prompt, &params, &mut h1).unwrap();
    assert!(!first.stats.pool_warm_start, "cache must start cold");
    let mut h2 = PoolHandle::shared(cache.clone());
    let warm = e.generate_with_pool(&rt, &prompt, &params, &mut h2).unwrap();

    assert!(warm.stats.pool_warm_start, "repeat request must start warm");
    assert!(warm.stats.pool_shared);
    assert_eq!(warm.tokens, cold.tokens, "sharing changed greedy output bytes");
    // A warm cache changes which G candidates each step verifies, so the
    // step trajectory may diverge from the cold run; allow a small slack
    // rather than demanding per-prompt monotonicity (the shared_cache
    // bench measures the mean improvement across a suite).
    assert!(
        warm.stats.compression() >= cold.stats.compression() - 0.25,
        "warm accept length {:.3} collapsed vs cold {:.3}",
        warm.stats.compression(),
        cold.stats.compression()
    );
    assert!(warm.stats.pool_hits > 0, "warm run never hit the pool");
    assert!(cache.stats().hits > 0, "warm run never hit the shared cache");
}

fn server_cfg(share: bool) -> ServerConfig {
    ServerConfig::builder().queue_depth(64).share_ngrams(share).build()
}

fn req(prompt: &str) -> Request {
    Request::new(prompt).max_tokens(24)
}

#[test]
fn share_toggle_through_scheduler_and_worker() {
    if no_artifacts() {
        return;
    }
    let prompt = "def cap_xy(x, y):\n    result = x";

    // sharing on: the second identical request starts warm
    let h = ServerHandle::start(server_cfg(true)).unwrap();
    let r1 = h.submit(req(prompt)).unwrap().wait().unwrap();
    let r2 = h.submit(req(prompt)).unwrap().wait().unwrap();
    assert!(r1.error.is_none() && r2.error.is_none(), "{:?} {:?}", r1.error, r2.error);
    assert!(r1.pool_shared && r2.pool_shared);
    assert!(!r1.pool_warm, "first request must be cold");
    assert!(r2.pool_warm, "second request must reuse the shared cache");
    assert_eq!(r1.text, r2.text, "sharing changed output");
    let warm = h.metrics.lock().counter("ngram_warm_requests");
    assert_eq!(warm, 1);
    assert!(h.report().contains("ngram_cache _shared/tiny:lookahead:n3"));

    // per-request opt-out under a sharing server
    let mut opt_out = req(prompt);
    opt_out.share_ngrams = Some(false);
    let r3 = h.submit(opt_out).unwrap().wait().unwrap();
    assert!(r3.error.is_none(), "{:?}", r3.error);
    assert!(!r3.pool_shared && !r3.pool_warm);
    assert_eq!(r3.text, r1.text);

    // sampled requests default to private pools under a sharing server
    // (seeded reproducibility; see Worker::bind_pool_for)
    let mut sampled = req(prompt);
    sampled.temperature = 0.8;
    sampled.seed = 7;
    let r4 = h.submit(sampled).unwrap().wait().unwrap();
    assert!(r4.error.is_none(), "{:?}", r4.error);
    assert!(!r4.pool_shared, "sampled request must not share by default");
    h.shutdown();

    // sharing off: repeat requests stay cold
    let h = ServerHandle::start(server_cfg(false)).unwrap();
    assert!(h.ngram_caches.is_none());
    let r1 = h.submit(req(prompt)).unwrap().wait().unwrap();
    let r2 = h.submit(req(prompt)).unwrap().wait().unwrap();
    assert!(r1.error.is_none() && r2.error.is_none());
    assert!(!r1.pool_shared && !r2.pool_shared);
    assert!(!r2.pool_warm, "sharing disabled but second request was warm");
    h.shutdown();
}
