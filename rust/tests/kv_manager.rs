//! KV-manager suite (simulated artifacts — runs without PJRT).
//!
//! Pins the tentpole claims of the `kv` subsystem:
//!   1. **Snapshot/restore**: a session suspended mid-generation and
//!      resumed — in-process, through the versioned on-disk snapshot, and
//!      on a *different* runtime instance (worker migration) — produces
//!      byte-identical tokens, deltas, and stats to an uninterrupted run,
//!      for ALL FIVE engines (prop-tested over random prompts/budgets/
//!      suspend points; spec-decode additionally round-trips its draft
//!      cache through the snapshot's `draft_kv` section).
//!   2. **Prefix reuse**: requests sharing a long prompt prefix fork a
//!      cached snapshot (`prefix_hits >= 1`), skip the full prefill, and
//!      still decode byte-identically to a cold runtime.
//!   3. **Suspend/resume serving**: a worker with `kv_budget` smaller than
//!      the offered load completes every request with no cross-talk, and
//!      the `kv_snapshots`/`kv_restores`/`suspended_sessions` metrics flow
//!      through the dispatcher metrics endpoint — plus a rotation-fairness
//!      property test under randomized open/cancel schedules.
//!   4. **Cross-worker rebalancing**: a parked snapshot donated through the
//!      `RebalanceHub` is adopted and finished byte-identically by another
//!      worker, and the client always receives its final record.

use std::collections::HashMap;
use std::rc::Rc;
use std::sync::Arc;

use lookahead::engine::autoregressive::AutoRegressive;
use lookahead::engine::jacobi::Jacobi;
use lookahead::engine::lookahead::Lookahead;
use lookahead::engine::prompt_lookup::PromptLookup;
use lookahead::engine::spec_decode::SpecDecode;
use lookahead::engine::{DecodeSession, Decoder, FinishReason, GenParams, StepOutcome};
use lookahead::kv::{KvManager, PrefixCache, SessionSnapshot};
use lookahead::ngram::PoolHandle;
use lookahead::runtime::sim::{ensure_sim_artifacts, ensure_slow_sim_artifacts};
use lookahead::runtime::{cpu_client, Manifest, ModelRuntime};
use lookahead::server::{Reply, Request, Response, ResponseStream, ServerConfig,
                        ServerHandle};
use lookahead::tokenizer::{ByteTokenizer, BOS_ID};
use lookahead::util::prop::forall;
use lookahead::util::rng::Rng;

fn sim_rt() -> ModelRuntime {
    let dir = ensure_sim_artifacts().unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let client = cpu_client().unwrap();
    ModelRuntime::load(&client, &manifest, "tiny").unwrap()
}

fn params(max: usize) -> GenParams {
    GenParams { max_new_tokens: max, ..Default::default() }
}

/// Drive a session to completion, returning (per-step deltas, finish).
fn drain(sess: &mut Box<dyn lookahead::engine::DecodeSession + '_>)
         -> (Vec<Vec<u32>>, FinishReason) {
    let mut deltas = Vec::new();
    loop {
        match sess.step().unwrap() {
            StepOutcome::Committed { tokens } => deltas.push(tokens),
            StepOutcome::Finished { reason } => return (deltas, reason),
        }
    }
}

/// Uninterrupted reference run.
fn reference(engine: &dyn Decoder, rt: &ModelRuntime, prompt: &[u32], p: &GenParams)
             -> (lookahead::engine::GenOutput, Vec<Vec<u32>>, FinishReason) {
    let pool = PoolHandle::for_spec(engine.pool_spec());
    let mut sess = engine.begin(rt, prompt, p, pool).unwrap();
    let (deltas, reason) = drain(&mut sess);
    let (out, _) = sess.into_output();
    (out, deltas, reason)
}

/// Resume a snapshot on `rt`, loading a draft runtime when the engine
/// needs one (the worker's `resume_snap` equivalent for tests).
fn resume_any<'rt>(snap: SessionSnapshot, rt: &'rt ModelRuntime)
                   -> Box<dyn DecodeSession + 'rt> {
    match snap.draft_model().map(str::to_string) {
        Some(name) => {
            let dir = ensure_sim_artifacts().unwrap();
            let manifest = Manifest::load(&dir).unwrap();
            let draft =
                Rc::new(ModelRuntime::load(&rt.client, &manifest, &name).unwrap());
            snap.resume_with(rt, Some(draft)).unwrap()
        }
        None => snap.resume(rt).unwrap(),
    }
}

/// Same request, suspended after `k` steps, optionally round-tripped
/// through the on-disk format, resumed on `resume_rt`.
fn with_suspend(engine: &dyn Decoder, rt: &ModelRuntime, resume_rt: &ModelRuntime,
                prompt: &[u32], p: &GenParams, k: usize, via_disk: bool)
                -> (lookahead::engine::GenOutput, Vec<Vec<u32>>, FinishReason) {
    let pool = PoolHandle::for_spec(engine.pool_spec());
    let mut sess = engine.begin(rt, prompt, p, pool).unwrap();
    let mut deltas = Vec::new();
    for _ in 0..k {
        if sess.finished().is_some() {
            break;
        }
        match sess.step().unwrap() {
            StepOutcome::Committed { tokens } => deltas.push(tokens),
            StepOutcome::Finished { .. } => break,
        }
    }
    if let Some(reason) = sess.finished() {
        // finished before the suspend point: nothing to suspend
        let (out, _) = sess.into_output();
        return (out, deltas, reason);
    }
    assert!(sess.suspendable(), "live session on sim artifacts must be suspendable");
    let snap = sess.suspend().unwrap();
    assert_eq!(sess.finished(), Some(FinishReason::Suspended));
    assert_eq!(
        sess.step().unwrap(),
        StepOutcome::Finished { reason: FinishReason::Suspended },
        "a suspended session must not step"
    );
    let snap = if via_disk {
        SessionSnapshot::from_bytes(&snap.to_bytes()).unwrap()
    } else {
        snap
    };
    let mut sess = resume_any(snap, resume_rt);
    let (rest, reason) = drain(&mut sess);
    deltas.extend(rest);
    let (out, _) = sess.into_output();
    (out, deltas, reason)
}

fn assert_identical(tag: &str,
                    a: &(lookahead::engine::GenOutput, Vec<Vec<u32>>, FinishReason),
                    b: &(lookahead::engine::GenOutput, Vec<Vec<u32>>, FinishReason)) {
    assert_eq!(a.0.tokens, b.0.tokens, "{tag}: tokens diverged");
    assert_eq!(a.0.text, b.0.text, "{tag}: text diverged");
    assert_eq!(a.1, b.1, "{tag}: per-step deltas diverged");
    assert_eq!(a.2, b.2, "{tag}: finish reason diverged");
    let (sa, sb) = (&a.0.stats, &b.0.stats);
    assert_eq!(sa.generated_tokens, sb.generated_tokens, "{tag}: generated_tokens");
    assert_eq!(sa.decode_steps, sb.decode_steps, "{tag}: decode_steps");
    assert_eq!(sa.accepted_by_len, sb.accepted_by_len, "{tag}: accept histogram");
    assert_eq!(sa.pool_hits, sb.pool_hits, "{tag}: pool_hits");
    assert_eq!(sa.pool_misses, sb.pool_misses, "{tag}: pool_misses");
    assert_eq!(sa.prompt_tokens, sb.prompt_tokens, "{tag}: prompt_tokens");
}

/// All five engines — every one is suspendable on cache_io-equipped
/// artifacts since the universal-suspend change.
fn engines() -> Vec<(&'static str, Box<dyn Decoder>)> {
    let dir = ensure_sim_artifacts().unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let client = cpu_client().unwrap();
    let draft = ModelRuntime::load(&client, &manifest, "draft").unwrap();
    vec![
        ("autoregressive", Box::new(AutoRegressive::new())),
        ("lookahead", Box::new(Lookahead::with_wng(5, 3, 5))),
        ("jacobi", Box::new(Jacobi::new(8))),
        ("prompt_lookup", Box::new(PromptLookup::new(8, 1))),
        ("spec_decode", Box::new(SpecDecode::new(draft, 4))),
    ]
}

#[test]
fn suspend_resume_is_byte_identical() {
    let rt = sim_rt();
    let rt2 = sim_rt(); // "another worker": independent runtime, same model
    let tok = ByteTokenizer::new();
    let prompt = tok.encode_with_bos("def add_ab(a, b):\n    result = a");
    let p = params(48);
    for (name, engine) in engines() {
        let want = reference(engine.as_ref(), &rt, &prompt, &p);
        for k in [0usize, 1, 3] {
            let inproc = with_suspend(engine.as_ref(), &rt, &rt, &prompt, &p, k, false);
            assert_identical(&format!("{name} in-process k={k}"), &inproc, &want);
            let disk = with_suspend(engine.as_ref(), &rt, &rt, &prompt, &p, k, true);
            assert_identical(&format!("{name} disk k={k}"), &disk, &want);
            let migrated = with_suspend(engine.as_ref(), &rt, &rt2, &prompt, &p, k, true);
            assert_identical(&format!("{name} migrated k={k}"), &migrated, &want);
        }
    }
}

#[test]
fn every_engine_is_suspendable_on_cache_io_artifacts() {
    let rt = sim_rt();
    let tok = ByteTokenizer::new();
    let prompt = tok.encode_with_bos("Q: what is 1 + 1?\n");
    for (name, engine) in engines() {
        let pool = PoolHandle::for_spec(engine.pool_spec());
        let sess = engine.begin(&rt, &prompt, &params(8), pool).unwrap();
        assert!(sess.suspendable(), "{name} must be suspendable under --kv-budget");
    }
}

#[test]
fn spec_decode_resume_demands_its_draft_runtime() {
    let rt = sim_rt();
    let tok = ByteTokenizer::new();
    let prompt = tok.encode_with_bos("def g(a):\n    return a");
    let (_, engine) = engines().pop().unwrap();
    let mut sess = engine.begin(&rt, &prompt, &params(16), PoolHandle::none()).unwrap();
    sess.step().unwrap();
    let snap = sess.suspend().unwrap();
    assert_eq!(snap.draft_model(), Some("draft"));
    assert!(snap.draft_kv.is_some(), "spec suspend must capture the draft cache");
    // resume() without a draft runtime must error, not panic or corrupt
    let bytes = snap.to_bytes();
    assert!(SessionSnapshot::from_bytes(&bytes).unwrap().resume(&rt).is_err());
    // a draft runtime for the wrong model is rejected
    let dir = ensure_sim_artifacts().unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let wrong = Rc::new(ModelRuntime::load(&rt.client, &manifest, "tiny").unwrap());
    let snap = SessionSnapshot::from_bytes(&bytes).unwrap();
    assert!(snap.resume_with(&rt, Some(wrong)).is_err());
    // the right one resumes and finishes like the uninterrupted run
    let (_, engine) = engines().pop().unwrap();
    let want = reference(engine.as_ref(), &rt, &prompt, &params(16));
    let mut sess = resume_any(SessionSnapshot::from_bytes(&bytes).unwrap(), &rt);
    let (_, _) = drain(&mut sess);
    let (out, _) = sess.into_output();
    assert_eq!(out.tokens, want.0.tokens);
}

#[test]
fn prop_suspend_resume_any_split_point() {
    let rt = sim_rt();
    forall(
        20,
        77,
        |r: &mut Rng| {
            let plen = r.range(1, 40);
            let mut prompt = vec![BOS_ID];
            prompt.extend((0..plen).map(|_| r.below(250) as u32));
            let k = r.range(0, 7);
            let max = r.range(4, 48);
            (prompt, k, max)
        },
        |(prompt, k, max)| {
            let p = params(*max);
            for (name, engine) in engines() {
                let want = reference(engine.as_ref(), &rt, prompt, &p);
                for via_disk in [false, true] {
                    let got = with_suspend(engine.as_ref(), &rt, &rt, prompt, &p, *k,
                                           via_disk);
                    if got.0.tokens != want.0.tokens {
                        return Err(format!(
                            "{name} (disk={via_disk}) tokens {:?} != {:?}",
                            got.0.tokens, want.0.tokens));
                    }
                    if got.1 != want.1 {
                        return Err(format!("{name} (disk={via_disk}) deltas diverged"));
                    }
                    let (gs, ws) = (&got.0.stats, &want.0.stats);
                    if (gs.decode_steps, gs.generated_tokens, &gs.accepted_by_len)
                        != (ws.decode_steps, ws.generated_tokens, &ws.accepted_by_len)
                    {
                        return Err(format!("{name} (disk={via_disk}) stats diverged"));
                    }
                    if (gs.pool_hits, gs.pool_misses) != (ws.pool_hits, ws.pool_misses) {
                        return Err(format!("{name} (disk={via_disk}) pool stats diverged"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn kv_manager_parks_and_migrates_real_sessions() {
    let rt = sim_rt();
    let tok = ByteTokenizer::new();
    let prompt = tok.encode_with_bos("def mul_xy(x, y):\n    return x");
    let p = params(32);
    let engine = AutoRegressive::new();
    let want = reference(&engine, &rt, &prompt, &p);

    let mut sess = engine.begin(&rt, &prompt, &p, PoolHandle::none()).unwrap();
    let mut deltas = Vec::new();
    if let StepOutcome::Committed { tokens } = sess.step().unwrap() {
        deltas.push(tokens);
    }
    let mut kv = KvManager::new();
    let h = kv.park(sess.suspend().unwrap());
    assert_eq!(kv.stats().parked, 1);
    assert!(kv.stats().parked_bytes > 0);

    // round-trip through disk (the migration file)
    let dir = std::env::temp_dir().join(format!("la-kvtest-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mig.kvsnap");
    kv.save(h, &path).unwrap();
    let mut kv2 = KvManager::new();
    let h2 = kv2.load(&path).unwrap();
    let mut sess = kv2.revive(h2).unwrap().resume(&rt).unwrap();
    let (rest, reason) = drain(&mut sess);
    deltas.extend(rest);
    let (out, _) = sess.into_output();
    assert_identical("kv-manager migration", &(out, deltas, reason), &want);
    assert_eq!(kv2.stats().restores, 1);
}

// ---------------------------------------------------------------------------
// prefix reuse
// ---------------------------------------------------------------------------

#[test]
fn prefix_reuse_skips_prefill_and_stays_byte_identical() {
    let cold = sim_rt(); // reference runtime without a prefix cache
    let tok = ByteTokenizer::new();
    let sys = "You are a helpful assistant."; // 28 bytes + BOS = 29 shared tokens
    let p1 = tok.encode_with_bos(&format!("{sys} Q1: add?"));
    let p2 = tok.encode_with_bos(&format!("{sys} Q2: mul?"));
    let p = params(24);

    for (name, engine) in engines() {
        // fresh runtime + trie per engine so hit/miss counts start clean
        let rt = sim_rt();
        let pc = Arc::new(PrefixCache::new(16, 8));
        rt.set_prefix_cache(Some(pc.clone()));

        // first request: miss + insert
        let (one, _, _) = reference(engine.as_ref(), &rt, &p1, &p);
        let st1 = pc.stats();
        assert!(st1.misses >= 1, "{name}: first prompt must miss");
        assert!(st1.inserts >= 1, "{name}: first prompt must insert");

        // shared-prefix request: forks the snapshot (partial hit)
        let (two, _, _) = reference(engine.as_ref(), &rt, &p2, &p);
        let st2 = pc.stats();
        assert!(st2.hits > st1.hits, "{name}: shared prefix must hit");

        // exact repeat: hits again, zero extension
        let (one_again, _, _) = reference(engine.as_ref(), &rt, &p1, &p);
        assert!(pc.stats().hits > st2.hits, "{name}: exact repeat must hit");

        // byte-identity against the cold runtime
        let (cold_one, _, _) = reference(engine.as_ref(), &cold, &p1, &p);
        let (cold_two, _, _) = reference(engine.as_ref(), &cold, &p2, &p);
        assert_eq!(one.tokens, cold_one.tokens, "{name}: p1 diverged under reuse");
        assert_eq!(one_again.tokens, cold_one.tokens,
                   "{name}: exact-hit p1 diverged under reuse");
        assert_eq!(two.tokens, cold_two.tokens, "{name}: p2 diverged under reuse");
        assert_eq!(two.text, cold_two.text);

        let st = pc.stats();
        assert!(st.bytes_reused > 0, "{name}: forks must count reused bytes");
        assert!(st.entries >= 2, "{name}: both prompts should be cached");
    }
}

#[test]
fn short_prompts_bypass_the_prefix_cache() {
    let rt = sim_rt();
    let pc = Arc::new(PrefixCache::new(32, 8));
    rt.set_prefix_cache(Some(pc.clone()));
    let tok = ByteTokenizer::new();
    let prompt = tok.encode_with_bos("hi"); // far below min_prefix
    let engine = AutoRegressive::new();
    let _ = reference(&engine, &rt, &prompt, &params(8));
    let _ = reference(&engine, &rt, &prompt, &params(8));
    let st = pc.stats();
    assert_eq!(st.entries, 0, "short prompts must not be cached");
    assert_eq!(st.hits, 0);
}

// ---------------------------------------------------------------------------
// serving: budgeted suspend/resume + metrics endpoint
// ---------------------------------------------------------------------------

fn serve_cfg(dir: &str, workers: usize, max_live: usize, kv_budget: usize,
             prefix: bool, rebalance: bool, rebalance_interval_ms: u64)
             -> ServerConfig {
    ServerConfig::builder()
        .workers(workers)
        .queue_depth(64)
        .share_ngrams(false)
        .rebalance(rebalance)
        .rebalance_interval_ms(rebalance_interval_ms)
        .artifacts_dir(dir)
        .time_slice(2)
        .max_live(max_live)
        .kv_budget(kv_budget)
        .prefix_cache(prefix)
        .build()
}

/// The serving-side engine equivalents (must mirror `Worker::make_engine`).
fn engine_for(method: &str, rt: &ModelRuntime) -> Box<dyn Decoder> {
    match method {
        "lookahead" => Box::new(Lookahead::with_wng(5, 3, 5)),
        "jacobi" => Box::new(Jacobi::new(8)),
        "prompt_lookup" => Box::new(PromptLookup::new(8, 1)),
        "spec_decode" => {
            let dir = ensure_sim_artifacts().unwrap();
            let manifest = Manifest::load(&dir).unwrap();
            let draft = ModelRuntime::load(&rt.client, &manifest, "draft").unwrap();
            Box::new(SpecDecode::new(draft, 4))
        }
        _ => Box::new(AutoRegressive::new()),
    }
}

/// Drain a reply stream: (concatenated chunk deltas, final record).
fn collect(rx: ResponseStream) -> (String, Response) {
    let mut cat = String::new();
    loop {
        match rx.recv().unwrap() {
            Reply::Chunk(c) => cat.push_str(&c.delta),
            Reply::Done(r) => return (cat, r),
        }
    }
}

#[test]
fn kv_budget_serves_overload_with_no_cross_talk() {
    let dir = ensure_sim_artifacts().unwrap();
    let dir_s = dir.to_string_lossy().into_owned();
    // budget of 2 device caches, 6 concurrent sessions offered — one per
    // engine plus repeats, so every engine exercises the park/revive path
    let h = ServerHandle::start(serve_cfg(&dir_s, 1, 6, 2, false, false, 50)).unwrap();

    let prompts = [
        ("def f_a(x):\n    return x", "autoregressive"),
        ("def f_b(x, y):\n    return y", "autoregressive"),
        ("Q: what is 12 + 34?\n", "lookahead"),
        ("Once upon a time there was", "lookahead"),
        ("for i in range(10): print(i)", "jacobi"),
        ("abc abc abc abc abc", "prompt_lookup"),
        ("def spec_tgt(n):\n    return n", "spec_decode"),
    ];
    let rxs: Vec<_> = prompts
        .iter()
        .map(|(prompt, method)| {
            h.submit(Request::new(*prompt).max_tokens(40).method(*method)).unwrap()
        })
        .collect();
    let resps: Vec<_> = rxs.into_iter().map(|rx| rx.wait().unwrap()).collect();

    // every request completed, byte-identical to a solo run (no cross-talk)
    let rt = sim_rt();
    let tok = ByteTokenizer::new();
    for ((prompt, method), resp) in prompts.iter().zip(&resps) {
        assert!(resp.error.is_none(), "{method} '{prompt}': {:?}", resp.error);
        let engine = engine_for(method, &rt);
        let ids = tok.encode_with_bos(prompt);
        let (want, _, _) = reference(engine.as_ref(), &rt, &ids, &params(40));
        assert_eq!(resp.text, want.text, "{method} '{prompt}' diverged under budget");
        assert_eq!(resp.tokens, want.stats.generated_tokens);
    }

    // the suspend/resume path demonstrably ran, and the metrics flow
    // through the dispatcher metrics endpoint
    let (snaps, restores) = {
        let m = h.metrics.lock();
        (m.counter("kv_snapshots"), m.counter("kv_restores"))
    };
    assert!(snaps >= 1, "over-budget load must park sessions (snapshots={snaps})");
    assert!(restores >= 1, "parked sessions must be revived (restores={restores})");
    let report = h.report();
    assert!(report.contains("kv_snapshots"), "metrics endpoint must report kv:\n{report}");
    assert!(report.contains("suspended_sessions"),
            "metrics endpoint must carry the suspended gauge:\n{report}");
    assert!(report.contains("live_sessions"),
            "metrics endpoint must carry the queue-depth report:\n{report}");

    // worker shutdown must zero its gauges (they are summed by the report:
    // a stale per-worker value would inflate it forever)
    let metrics = h.metrics.clone();
    h.shutdown();
    let m = metrics.lock();
    assert_eq!(m.counter("suspended_sessions_w0"), 0,
               "suspended gauge must be zeroed on worker exit");
    assert_eq!(m.counter("live_sessions_w0"), 0,
               "live gauge must be zeroed on worker exit");
}

#[test]
fn prop_rotation_fairness_under_budget_saturation() {
    // Sustained kv-budget saturation with randomized open/cancel schedules
    // across all five engines: every uncancelled session must finish with
    // output byte-identical to a solo run (i.e. every parked session keeps
    // making progress — a park/revive livelock would hang this test), and
    // every cancelled session must still get a well-formed final record.
    let dir = ensure_sim_artifacts().unwrap();
    let dir_s = dir.to_string_lossy().into_owned();
    let h = ServerHandle::start(serve_cfg(&dir_s, 1, 8, 2, false, false, 50)).unwrap();
    let rt = sim_rt();
    let tok = ByteTokenizer::new();
    let methods =
        ["autoregressive", "lookahead", "jacobi", "prompt_lookup", "spec_decode"];
    let prompts = [
        "def rotate_a(x):\n    return x + 1",
        "Q: how many rounds until fairness?\n",
        "abc abc abc abc abc abc",
        "Once upon a budget there was a queue",
    ];
    let mut solo: HashMap<(usize, usize), lookahead::engine::GenOutput> =
        HashMap::new();
    let mut rng = Rng::new(0xFA13);
    for round in 0..5u32 {
        let n = rng.range(4, 9); // oversubscribe the budget of 2
        let mut subs = Vec::new();
        for _ in 0..n {
            let (mi, pi) = (rng.below(methods.len()), rng.below(prompts.len()));
            let stream = rng.below(2) == 1;
            let cancel = rng.below(4) == 0;
            let rx = h
                .submit(Request::new(prompts[pi])
                    .max_tokens(24)
                    .method(methods[mi])
                    .stream(stream))
                .unwrap();
            subs.push((mi, pi, stream, cancel, rx));
        }
        for (_, _, _, cancel, rx) in &subs {
            if *cancel {
                h.cancel(rx.id); // races admission/steps on purpose
            }
        }
        for (mi, pi, stream, cancelled, rx) in subs {
            let (cat, r) = collect(rx);
            assert!(r.error.is_none(),
                    "round {round} {}: {:?}", methods[mi], r.error);
            assert!(!r.finish.is_empty(),
                    "round {round} {}: record must carry a finish reason",
                    methods[mi]);
            if stream {
                assert_eq!(cat, r.text,
                           "round {round} {}: chunks must concatenate to the \
                            final text", methods[mi]);
            }
            if !cancelled {
                let want = solo.entry((mi, pi)).or_insert_with(|| {
                    let engine = engine_for(methods[mi], &rt);
                    let ids = tok.encode_with_bos(prompts[pi]);
                    reference(engine.as_ref(), &rt, &ids, &params(24)).0
                });
                assert_eq!(r.text, want.text,
                           "round {round}: {} x '{}' diverged under rotation",
                           methods[mi], prompts[pi]);
                assert_eq!(r.tokens, want.stats.generated_tokens);
            }
        }
    }
    let snaps = h.metrics.lock().counter("kv_snapshots");
    assert!(snaps >= 1, "the schedule must actually saturate the budget");
    h.shutdown();
}

#[test]
fn serving_prefix_hits_flow_through_metrics() {
    let dir = ensure_sim_artifacts().unwrap();
    let dir_s = dir.to_string_lossy().into_owned();
    let h = ServerHandle::start(serve_cfg(&dir_s, 1, 2, 0, true, false, 50)).unwrap();

    // >= 32 shared prompt tokens (BOS + 39 bytes), distinct tails
    let sys = "System: you are a terse coding assistant";
    let mk = |tail: &str| {
        Request::new(format!("{sys}{tail}")).max_tokens(12).method("autoregressive")
    };
    // serialize the two requests so the first inserts before the second opens
    let r1 = h.submit(mk(" one")).unwrap().wait().unwrap();
    assert!(r1.error.is_none(), "{:?}", r1.error);
    let r2 = h.submit(mk(" two")).unwrap().wait().unwrap();
    assert!(r2.error.is_none(), "{:?}", r2.error);

    let pc = h.prefix_cache.as_ref().expect("prefix cache enabled").clone();
    let st = pc.stats();
    assert!(st.hits >= 1,
            "second request shares a {}+ token prefix and must skip its prefill: {st:?}",
            sys.len() + 1);
    let report = h.report();
    assert!(report.contains("prefix_hits"), "metrics endpoint must report:\n{report}");
    assert!(report.contains("prefix_cache:"), "report must carry the trie line:\n{report}");
    h.shutdown();
}

// ---------------------------------------------------------------------------
// cross-worker rebalancing
// ---------------------------------------------------------------------------

#[test]
fn rebalance_migrates_parked_sessions_across_workers() {
    // Two workers, kv_budget 1, a sustained burst across all five engines
    // on slow sim artifacts (identical token streams, ~5ms per decode
    // launch — sessions live long enough to be parked and shipped). The
    // policy thread is parked on an hour-long interval so the test drives
    // donation deterministically through the hub, exactly as the policy
    // would.
    let dir = ensure_slow_sim_artifacts().unwrap();
    let dir_s = dir.to_string_lossy().into_owned();
    let h =
        ServerHandle::start(serve_cfg(&dir_s, 2, 6, 1, false, true, 3_600_000))
            .unwrap();
    let hub = h.rebalance.as_ref().expect("two rebalancing workers").clone();

    let methods =
        ["autoregressive", "lookahead", "jacobi", "prompt_lookup", "spec_decode"];
    let load: Vec<(String, &str, bool)> = (0..10)
        .map(|i| {
            (format!("def burst_{i}(x):\n    return x + {i}"), methods[i % 5],
             i % 3 == 0)
        })
        .collect();
    let rxs: Vec<_> = load
        .iter()
        .map(|(prompt, method, stream)| {
            h.submit(Request::new(prompt.clone())
                .max_tokens(48)
                .method(*method)
                .stream(*stream))
            .unwrap()
        })
        .collect();

    // steer: whenever a worker holds parked sessions, direct a donation to
    // the other one, until at least one migration lands
    for _ in 0..1000 {
        if hub.moves() >= 1 {
            break;
        }
        let loads = hub.loads();
        if let Some(donor) = (0..loads.len())
            .filter(|&w| loads[w].parked > 0)
            .max_by_key(|&w| loads[w].depth())
        {
            hub.direct(donor, 1 - donor);
        }
        lookahead::util::sync::nap(std::time::Duration::from_millis(2));
    }
    assert!(hub.moves() >= 1,
            "a parked session must migrate under sustained imbalance: {:?}",
            hub.loads());

    // every request still completes byte-identically to a solo run (the
    // fast and slow sim variants produce identical token streams)
    let rt = sim_rt();
    let tok = ByteTokenizer::new();
    for ((prompt, method, stream), rx) in load.iter().zip(rxs) {
        let (cat, r) = collect(rx);
        assert!(r.error.is_none(), "{method} '{prompt}': {:?}", r.error);
        let engine = engine_for(method, &rt);
        let ids = tok.encode_with_bos(prompt);
        let (want, _, _) = reference(engine.as_ref(), &rt, &ids, &params(48));
        assert_eq!(r.text, want.text, "{method} '{prompt}' diverged after migration");
        if *stream {
            assert_eq!(cat, r.text,
                       "{method} '{prompt}': a migrated stream must still \
                        concatenate to the final text");
        }
    }
    let m = h.metrics.lock();
    assert!(m.counter("rebalanced_sessions") >= 1,
            "the donor must count its hand-offs");
    assert!(m.counter("rebalance_adopted") >= 1,
            "the adopter must count arrivals");
    drop(m);
    h.shutdown();
}

#[test]
fn rebalance_policy_thread_keeps_serving_correctly() {
    // End-to-end smoke over the autonomous policy thread: fast artifacts,
    // a 2ms scan interval, and an oversubscribed two-worker server. The
    // migrations themselves are timing-dependent — what this pins is that
    // whatever the rebalancer does, every response stays byte-identical.
    let dir = ensure_sim_artifacts().unwrap();
    let dir_s = dir.to_string_lossy().into_owned();
    let h = ServerHandle::start(serve_cfg(&dir_s, 2, 4, 1, false, true, 2)).unwrap();
    let methods =
        ["autoregressive", "lookahead", "jacobi", "prompt_lookup", "spec_decode"];
    let load: Vec<(String, &str)> = (0..8)
        .map(|i| (format!("Q: smoke number {i}?\n"), methods[i % 5]))
        .collect();
    let rxs: Vec<_> = load
        .iter()
        .map(|(prompt, method)| {
            h.submit(Request::new(prompt.clone()).max_tokens(32).method(*method))
                .unwrap()
        })
        .collect();
    let rt = sim_rt();
    let tok = ByteTokenizer::new();
    for ((prompt, method), rx) in load.iter().zip(rxs) {
        let r = rx.wait().unwrap();
        assert!(r.error.is_none(), "{method} '{prompt}': {:?}", r.error);
        let engine = engine_for(method, &rt);
        let ids = tok.encode_with_bos(prompt);
        let (want, _, _) = reference(engine.as_ref(), &rt, &ids, &params(32));
        assert_eq!(r.text, want.text, "{method} '{prompt}' diverged under rebalance");
    }
    h.shutdown();
}
