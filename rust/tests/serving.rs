//! Integration tests over the serving front (in-process + TCP) and the
//! lookahead-parallelism simulation, against real artifacts. Tests using
//! real artifacts skip when `artifacts/` is absent (CI runs without PJRT);
//! the rebalanced-serving test targets the simulated artifact set and
//! always runs.

use lookahead::layout::Wng;
use lookahead::runtime::{cpu_client, Manifest, ModelRuntime};
use lookahead::server::{client_request, serve_tcp, Request, ServerConfig,
                        ServerHandle};
use lookahead::util::json::Json;

/// Skip (returning true) when the AOT artifacts are not built.
fn no_artifacts() -> bool {
    lookahead::bench::skip_without_artifacts(module_path!())
}

fn cfg() -> ServerConfig {
    ServerConfig::builder().queue_depth(64).build()
}

#[test]
fn inprocess_serving_roundtrip() {
    if no_artifacts() {
        return;
    }
    let h = ServerHandle::start(cfg()).unwrap();
    let rx = h
        .submit(Request::new("def add_ab(a, b):\n    result = a").max_tokens(24))
        .unwrap();
    let resp = rx.wait().unwrap();
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert!(resp.tokens > 0);
    assert!(resp.compression >= 1.0);
    assert!(!resp.finish.is_empty(), "finish reason must be reported");
    let m = h.metrics.lock().counter("responses_ok");
    assert_eq!(m, 1);
    h.shutdown();
}

#[test]
fn serving_multiple_requests_and_methods() {
    if no_artifacts() {
        return;
    }
    let h = ServerHandle::start(cfg()).unwrap();
    let mut rxs = Vec::new();
    for (i, method) in ["lookahead", "autoregressive", "prompt_lookup"]
        .iter()
        .enumerate()
    {
        rxs.push(h.submit(
            Request::new(format!("Q: what is {} + {}?\n", 10 + i, 20 + i))
                .max_tokens(16)
                .method(*method),
        ).unwrap());
    }
    // same prompt+greedy across exact methods must give identical text
    let texts: Vec<String> = rxs.into_iter().map(|rx| {
        let r = rx.wait().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        r.text
    }).collect();
    assert_eq!(texts.len(), 3);
    h.shutdown();
}

#[test]
fn unknown_method_reports_error() {
    if no_artifacts() {
        return;
    }
    let h = ServerHandle::start(cfg()).unwrap();
    let rx = h.submit(Request::new("x").method("warp_drive")).unwrap();
    let resp = rx.wait().unwrap();
    assert!(resp.error.is_some());
    h.shutdown();
}

#[test]
fn tcp_roundtrip_json_lines() {
    if no_artifacts() {
        return;
    }
    let addr = "127.0.0.1:17878";
    let server = std::thread::spawn(move || {
        serve_tcp(addr, cfg(), Some(1)).unwrap();
    });
    // wait for bind
    lookahead::util::sync::nap(std::time::Duration::from_millis(300));
    let resp = client_request(
        addr,
        r#"{"prompt": "user: how does the cache work?\n", "max_tokens": 16}"#,
    )
    .unwrap();
    let j = Json::parse(&resp).unwrap();
    assert!(j.get("error").is_none(), "{resp}");
    assert!(j.get("tokens").unwrap().as_usize().unwrap() > 0);
    server.join().unwrap();
}

#[test]
fn rebalanced_two_worker_server_reports_and_serves() {
    // Runs on simulated artifacts (no PJRT needed): a two-worker server
    // with rebalancing on serves a small burst, and the metrics endpoint
    // carries the queue-depth report the rebalancer reads.
    let dir = lookahead::runtime::sim::ensure_sim_artifacts().unwrap();
    let c = ServerConfig::builder()
        .workers(2)
        .queue_depth(64)
        .rebalance(true)
        .rebalance_interval_ms(5)
        .artifacts_dir(dir.to_string_lossy().into_owned())
        .kv_budget(1)
        .build();
    let h = ServerHandle::start(c).unwrap();
    assert!(h.rebalance.is_some(), "two workers + rebalance:true must build a hub");
    let rxs: Vec<_> = (0..4)
        .map(|i| {
            h.submit(
                Request::new(format!("def r{i}(x):\n    return x"))
                    .max_tokens(16)
                    .method("autoregressive"),
            )
            .unwrap()
        })
        .collect();
    for rx in rxs {
        let r = rx.wait().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert!(r.tokens > 0);
    }
    let report = h.report();
    assert!(report.contains("queue_depth"),
            "report must carry the queue-depth gauge:\n{report}");
    assert!(report.contains("live_sessions"),
            "report must carry the summed live gauge:\n{report}");
    let metrics = h.metrics.clone();
    h.shutdown();
    let m = metrics.lock();
    for w in 0..2 {
        assert_eq!(m.counter(&format!("suspended_sessions_w{w}")), 0,
                   "worker {w} must zero its suspended gauge on exit");
        assert_eq!(m.counter(&format!("live_sessions_w{w}")), 0,
                   "worker {w} must zero its live gauge on exit");
    }
}

#[test]
fn rebalancer_ships_parked_sessions_to_a_loopback_peer() {
    // Two servers connected only over TCP loopback: the front (donor) runs
    // one KV-starved worker with rebalancing on; the back (adopter) exposes
    // a peer listener. The rebalance policy must pick the remote
    // pseudo-worker, the snapshot must stream across, and the migrated
    // sessions must produce exactly the text a solo server produces.
    let dir = lookahead::runtime::sim::ensure_slow_sim_artifacts()
        .unwrap()
        .to_string_lossy()
        .into_owned();
    let back = ServerHandle::start(
        ServerConfig::builder()
            .queue_depth(64)
            .artifacts_dir(dir.clone())
            .peer_addr(Some("127.0.0.1:18841".into()))
            .build(),
    )
    .unwrap();
    let front = ServerHandle::start(
        ServerConfig::builder()
            .queue_depth(64)
            .artifacts_dir(dir.clone())
            .rebalance(true)
            .rebalance_interval_ms(5)
            .kv_budget(1)
            .peers(vec!["127.0.0.1:18841".into()])
            .heartbeat_ms(5)
            .build(),
    )
    .unwrap();
    // the heartbeat must observe the peer alive before load arrives
    let peers = front.peers.clone().expect("peer table");
    for _ in 0..400 {
        if peers.snapshot().iter().any(|p| p.alive) {
            break;
        }
        lookahead::util::sync::nap(std::time::Duration::from_millis(5));
    }
    assert!(peers.snapshot().iter().any(|p| p.alive), "peer never came up");

    let prompts: Vec<String> =
        (0..4).map(|i| format!("def r{i}(x):\n    return x")).collect();
    let rxs: Vec<_> = prompts
        .iter()
        .map(|p| {
            front
                .submit(Request::new(p.clone()).max_tokens(16).method("autoregressive"))
                .unwrap()
        })
        .collect();
    let texts: Vec<String> = rxs
        .into_iter()
        .map(|rx| {
            let r = rx.wait().unwrap();
            assert!(r.error.is_none(), "{:?}", r.error);
            r.text
        })
        .collect();

    let (transfers, adopted, bounced) = {
        let m = front.metrics.lock();
        (m.counter("net_transfers"), m.counter("net_adopted"),
         m.counter("net_bounced"))
    };
    assert!(transfers >= 1, "rebalancer never shipped a session over the wire");
    assert_eq!(adopted + bounced, transfers,
               "every transfer must settle as adopted or bounced");
    front.shutdown();
    back.shutdown();

    // solo reference: the same prompts, one ordinary server, no networking
    let solo = ServerHandle::start(
        ServerConfig::builder().queue_depth(64).artifacts_dir(dir).build(),
    )
    .unwrap();
    for (p, migrated) in prompts.iter().zip(&texts) {
        let r = solo
            .submit(Request::new(p.clone()).max_tokens(16).method("autoregressive"))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(&r.text, migrated, "migrated text must match the solo run");
    }
    solo.shutdown();
}

#[test]
fn lp_simulation_scales_down_shard_time() {
    if no_artifacts() {
        return;
    }
    let manifest = Manifest::load("artifacts").unwrap();
    let client = cpu_client().unwrap();
    let rt = ModelRuntime::load(&client, &manifest, "tiny").unwrap();
    let ids: Vec<u32> = "warm prompt".bytes().map(|b| b as u32).collect();
    let (_, cache) = rt.prefill(&ids).unwrap();
    let wng = Wng::new(15, 5, 15);
    let r1 = lookahead::lp::simulate(&rt, &cache, wng, 1, 2.0, 3).unwrap();
    let r4 = lookahead::lp::simulate(&rt, &cache, wng, 4, 2.0, 3).unwrap();
    // 4-way sharding must reduce the simulated step latency (strong scaling)
    assert!(
        r4.step_ms < r1.step_ms,
        "LP did not scale: 1 dev {:.2}ms vs 4 dev {:.2}ms",
        r1.step_ms,
        r4.step_ms
    );
    assert!(r4.tokens_per_sec > r1.tokens_per_sec);
}
