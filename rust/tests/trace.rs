//! Structured-tracing suite (simulated artifacts — runs without PJRT).
//!
//! End-to-end coverage for the span recorder wired through the serving
//! stack: session lifecycles emit ordered spans under one `trace_id`
//! (solo, parked/revived, controller-driven, and migrated across two
//! server processes), sampling and the per-request `"trace"` flag gate
//! minting, the Chrome export validates, and tracing disabled leaves the
//! wire format byte-compatible (no new keys) at zero span cost.

use std::time::Duration;

use lookahead::server::{Request, Response, ServerConfig, ServerHandle};
use lookahead::trace::{self, Span, Tracer};
use lookahead::util::json::Json;

fn sim_dir() -> String {
    lookahead::runtime::sim::ensure_sim_artifacts()
        .unwrap()
        .to_string_lossy()
        .into_owned()
}

fn traced_server(dir: &str) -> ServerHandle {
    ServerHandle::start(
        ServerConfig::builder()
            .queue_depth(64)
            .workers(1)
            .artifacts_dir(dir.to_string())
            .trace(true)
            .build(),
    )
    .unwrap()
}

fn run_traced(h: &ServerHandle, prompt: &str, max_tokens: usize) -> Response {
    let rx = h
        .submit(
            Request::new(prompt)
                .max_tokens(max_tokens)
                .method("autoregressive")
                .trace(true),
        )
        .unwrap();
    let r = rx.wait().unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    r
}

/// Spans of one session, in time order.
fn session_spans(spans: &[Span], trace_id: u64) -> Vec<&Span> {
    spans.iter().filter(|s| s.trace_id == trace_id).collect()
}

fn first_start(spans: &[&Span], name: &str) -> u64 {
    spans
        .iter()
        .find(|s| s.name == name)
        .unwrap_or_else(|| panic!("no '{name}' span in {spans:?}"))
        .start_us
}

#[test]
fn solo_lifecycle_emits_ordered_spans_under_one_trace_id() {
    let dir = sim_dir();
    let h = traced_server(&dir);
    let r = run_traced(&h, "def solo(x):\n    return x", 16);

    let spans = h.tracer.as_ref().unwrap().snapshot();
    let ids: Vec<u64> = spans
        .iter()
        .filter(|s| s.trace_id != 0)
        .map(|s| s.trace_id)
        .collect();
    assert!(!ids.is_empty(), "a traced session must emit spans");
    let id = ids[0];
    assert!(ids.iter().all(|&i| i == id), "one session, one trace_id: {ids:?}");

    let sess = session_spans(&spans, id);
    let (admit, prefill, round) = (
        first_start(&sess, "admit"),
        first_start(&sess, "prefill"),
        first_start(&sess, "round"),
    );
    assert!(admit <= prefill, "admit must start before prefill");
    assert!(prefill <= round, "prefill must start before the first round");
    let pf = sess.iter().find(|s| s.name == "prefill").unwrap();
    assert!(
        pf.args.iter().any(|(k, v)| k == "mode" && (v == "cold" || v == "fork")),
        "prefill must be tagged cold|fork: {:?}",
        pf.args
    );
    let rd = sess.iter().find(|s| s.name == "round").unwrap();
    assert!(rd.args.iter().any(|(k, _)| k == "engine"),
            "round spans carry the engine tag: {:?}", rd.args);

    // the per-request timeline rides the final record and mirrors the
    // session's span names
    let tl = r.timeline.as_ref().expect("traced request must carry a timeline");
    let names: Vec<&str> = tl
        .as_arr()
        .unwrap()
        .iter()
        .filter_map(|e| e.get("name").and_then(Json::as_str))
        .collect();
    assert!(names.contains(&"admit"), "{names:?}");
    assert!(names.contains(&"round"), "{names:?}");

    // the live dump is schema-valid Chrome trace-event JSON
    trace::validate_trace_json(&h.trace_json().dump()).unwrap();

    // the report sync publishes the recorder's totals as gauges
    let report = h.report_json();
    let spans_gauge = report
        .path("counters.trace_spans")
        .and_then(Json::as_usize)
        .expect("report must carry the trace_spans gauge");
    assert!(spans_gauge > 0, "a traced run must report recorded spans");
    assert!(
        report.path("counters.trace_dropped").is_some(),
        "report must carry the trace_dropped gauge"
    );
    h.shutdown();
}

#[test]
fn sampling_gates_minting_and_the_request_flag_forces_it() {
    let dir = sim_dir();
    let h = ServerHandle::start(
        ServerConfig::builder()
            .queue_depth(64)
            .workers(1)
            .artifacts_dir(dir)
            .trace(true)
            .trace_sample(1000)
            .build(),
    )
    .unwrap();
    // sequential untraced requests: only admission 0 samples in
    for i in 0..3 {
        let rx = h
            .submit(Request::new(format!("def s{i}(x):\n    return x"))
                .max_tokens(8)
                .method("autoregressive"))
            .unwrap();
        let r = rx.wait().unwrap();
        assert!(r.error.is_none());
        assert!(r.timeline.is_none(),
                "sampled sessions get global spans, not per-request timelines");
    }
    let distinct = |spans: &[Span]| {
        let mut ids: Vec<u64> =
            spans.iter().filter(|s| s.trace_id != 0).map(|s| s.trace_id).collect();
        ids.sort_unstable();
        ids.dedup();
        ids.len()
    };
    let spans = h.tracer.as_ref().unwrap().snapshot();
    assert_eq!(distinct(&spans), 1,
               "sample 1000 must trace only the first of 3 admissions");
    // the per-request flag overrides the sampler
    let r = run_traced(&h, "def forced(x):\n    return x", 8);
    assert!(r.timeline.is_some());
    let spans = h.tracer.as_ref().unwrap().snapshot();
    assert_eq!(distinct(&spans), 2, "the forced request must mint a fresh id");
    h.shutdown();
}

#[test]
fn parked_and_revived_session_keeps_one_trace_id() {
    // slow sim (~ms per decode launch): the three sessions genuinely
    // coexist, so budget 1 must park and rotate them
    let dir = lookahead::runtime::sim::ensure_slow_sim_artifacts()
        .unwrap()
        .to_string_lossy()
        .into_owned();
    // device budget 1 with 3 interleaved sessions: admission overflow
    // parks, rotation revives — every session crosses the kv path
    let h = ServerHandle::start(
        ServerConfig::builder()
            .queue_depth(64)
            .workers(1)
            .max_live(4)
            .kv_budget(1)
            .artifacts_dir(dir)
            .trace(true)
            .build(),
    )
    .unwrap();
    let rxs: Vec<_> = (0..3)
        .map(|i| {
            h.submit(
                Request::new(format!("def park{i}(x):\n    return x + {i}"))
                    .max_tokens(24)
                    .method("autoregressive")
                    .trace(true),
            )
            .unwrap()
        })
        .collect();
    for rx in rxs {
        let r = rx.wait().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
    }
    let spans = h.tracer.as_ref().unwrap().snapshot();
    let parked: Vec<u64> = spans
        .iter()
        .filter(|s| s.name == "park" && s.trace_id != 0)
        .map(|s| s.trace_id)
        .collect();
    assert!(!parked.is_empty(), "budget 1 must park at least one session");
    let id = parked[0];
    let sess = session_spans(&spans, id);
    for name in ["admit", "prefill", "park", "revive", "round"] {
        assert!(sess.iter().any(|s| s.name == name),
                "parked session must keep its '{name}' span under one id");
    }
    let park = first_start(&sess, "park");
    let revive = sess
        .iter()
        .filter(|s| s.name == "revive")
        .map(|s| s.start_us)
        .max()
        .unwrap();
    assert!(park <= revive, "park must precede (a) revive");
    h.shutdown();
}

#[test]
fn adaptive_controller_emits_ctl_spans() {
    let dir = sim_dir();
    let h = ServerHandle::start(
        ServerConfig::builder()
            .queue_depth(64)
            .workers(1)
            .controller("adaptive")
            .artifacts_dir(dir)
            .trace(true)
            .build(),
    )
    .unwrap();
    // autoregressive commits 1 token/step, so a 64-token budget spans
    // many scheduling rounds — the controller observes every one. Three
    // prompts so one hitting a rare early sim EOS can't starve the test.
    for i in 0..3 {
        let _ = run_traced(&h, &format!("def ctl{i}(x):\n    return x * {i}"), 64);
    }
    let spans = h.tracer.as_ref().unwrap().snapshot();
    let decides: Vec<&Span> = spans
        .iter()
        .filter(|s| s.cat == "ctl" && s.name == "decide" && s.trace_id != 0)
        .collect();
    assert!(!decides.is_empty(), "adaptive sessions must emit decide spans");
    assert!(
        decides[0].args.iter().any(|(k, _)| k == "from")
            && decides[0].args.iter().any(|(k, _)| k == "to"),
        "decide spans carry from/to engine tags: {:?}",
        decides[0].args
    );
    // any applied switch is tagged with both engines under the same id
    for sw in spans.iter().filter(|s| s.name == "switch") {
        assert_eq!(sw.cat, "ctl");
        assert_ne!(sw.trace_id, 0);
        assert!(sw.args.iter().any(|(k, _)| k == "from"));
        assert!(sw.args.iter().any(|(k, _)| k == "to"));
    }
    h.shutdown();
}

#[test]
fn migrated_session_stitches_one_trace_id_across_servers() {
    let dir = sim_dir();
    let back = ServerHandle::start(
        ServerConfig::builder()
            .queue_depth(64)
            .workers(1)
            .artifacts_dir(dir.clone())
            .peer_addr(Some("127.0.0.1:18851".into()))
            .trace(true)
            .build(),
    )
    .unwrap();
    let front = ServerHandle::start(
        ServerConfig::builder()
            .queue_depth(64)
            .workers(1)
            .artifacts_dir(dir)
            .peers(vec!["127.0.0.1:18851".into()])
            .heartbeat_ms(5)
            .prefill_only(true)
            .trace(true)
            .build(),
    )
    .unwrap();
    // wait for the heartbeat to mark the decode peer alive
    let peers = front.peers.clone().expect("peer table");
    for _ in 0..400 {
        if peers.snapshot().iter().any(|p| p.alive) {
            break;
        }
        lookahead::util::sync::nap(Duration::from_millis(5));
    }

    let _ = run_traced(&front, "def mig(x):\n    return x + 1", 16);

    let merged = trace::merge_chrome(&[front.trace_json(), back.trace_json()]);
    trace::validate_trace_json(&merged.dump()).unwrap();
    let events = merged.get("traceEvents").unwrap().as_arr().unwrap().to_vec();
    // the donor minted the id at admission; the same hex id must tag the
    // donor-side prefill, the wire hop, and the adopter-side decode rounds
    let prefill_id = events
        .iter()
        .find(|e| e.get("name").and_then(Json::as_str) == Some("prefill"))
        .and_then(|e| e.path("args.trace_id"))
        .and_then(Json::as_str)
        .expect("donor prefill span with a trace_id")
        .to_string();
    let stitched: Vec<(String, String)> = events
        .iter()
        .filter(|e| {
            e.path("args.trace_id").and_then(Json::as_str)
                == Some(prefill_id.as_str())
        })
        .map(|e| {
            (e.get("name").and_then(Json::as_str).unwrap_or("?").to_string(),
             e.get("cat").and_then(Json::as_str).unwrap_or("?").to_string())
        })
        .collect();
    let has = |name: &str| stitched.iter().any(|(n, _)| n == name);
    assert!(has("prefill"), "stitched timeline missing prefill: {stitched:?}");
    assert!(has("transfer"), "stitched timeline missing the wire hop: {stitched:?}");
    assert!(has("adopt"), "stitched timeline missing adoption: {stitched:?}");
    assert!(has("round"),
            "stitched timeline missing adopter decode rounds: {stitched:?}");
    assert!(stitched.iter().any(|(_, c)| c == "net"),
            "stitched timeline must cross the net lane: {stitched:?}");
    front.shutdown();
    back.shutdown();
}

#[test]
fn ring_overflow_drops_oldest_and_counts_via_public_api() {
    let t = Tracer::new(1, 1, 8);
    for i in 0..20u64 {
        let t0 = t.now_us();
        t.push(t.span(0, 1, &format!("s{i}"), "decode", t0));
    }
    let (recorded, dropped) = t.stats();
    assert_eq!(recorded, 20);
    assert_eq!(dropped, 12);
    let snap = t.snapshot();
    assert_eq!(snap.len(), 8, "ring must hold exactly its capacity");
    assert!(snap.iter().all(|s| s.name != "s0"),
            "overflow must evict the oldest span first");
    assert_eq!(trace::trace_section(&t.chrome_json())
                   .get("dropped")
                   .and_then(Json::as_f64),
               Some(12.0));
}

#[test]
fn tracing_disabled_keeps_the_wire_format_and_yields_null_traces() {
    let dir = sim_dir();
    let plain = ServerHandle::start(
        ServerConfig::builder()
            .queue_depth(64)
            .workers(1)
            .artifacts_dir(dir.clone())
            .build(),
    )
    .unwrap();
    assert!(plain.tracer.is_none(), "tracing must default off");
    assert!(matches!(plain.trace_json(), Json::Null));

    // even a request ASKING for a trace gets no timeline when the server
    // records no spans — and no new keys appear on the wire
    let rx = plain
        .submit(
            Request::new("def off(x):\n    return x")
                .max_tokens(16)
                .method("autoregressive")
                .trace(true),
        )
        .unwrap();
    let r = rx.wait().unwrap();
    assert!(r.error.is_none());
    assert!(r.timeline.is_none());
    let line = r.to_json_line();
    assert!(!line.contains("timeline"), "untraced record grew a key: {line}");

    // the text is identical to a traced server's answer for the same
    // prompt (tracing must never perturb decode)
    let traced = traced_server(&dir);
    let rt = run_traced(&traced, "def off(x):\n    return x", 16);
    assert_eq!(r.text, rt.text, "tracing changed decode output");
    traced.shutdown();
    plain.shutdown();
}

#[test]
fn validator_gates_bad_dumps() {
    assert!(trace::validate_trace_json("nope").is_err());
    assert!(trace::validate_trace_json(r#"{"stats": {}}"#).is_err());
    assert!(trace::validate_trace_json(
        r#"{"traceEvents": [{"name": "x", "cat": "c", "ph": "X", "ts": 0}]}"#
    )
    .is_err());
    trace::validate_trace_json(r#"{"traceEvents": []}"#).unwrap();
}
