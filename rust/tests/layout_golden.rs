//! Cross-check the Rust layout canon against the Python-emitted golden file
//! (`artifacts/layout_golden.json`). Any drift between `masks.py` and
//! `rust/src/layout` means the coordinator would feed executables a layout
//! they were not lowered for — this test makes that impossible to miss.

use lookahead::layout::Wng;
use lookahead::util::json::Json;

/// Skip (returning true) when the AOT artifacts are not built.
fn no_artifacts() -> bool {
    lookahead::bench::skip_without_artifacts(module_path!())
}

#[test]
fn rust_layout_matches_python_golden() {
    if no_artifacts() {
        return;
    }
    let text = std::fs::read_to_string("artifacts/layout_golden.json")
        .expect("run `make artifacts` first");
    let j = Json::parse(&text).unwrap();
    let records = j.get("records").unwrap().as_arr().unwrap();
    assert!(records.len() >= 5);

    for rec in records {
        let w = rec.get("w").unwrap().as_usize().unwrap();
        let n = rec.get("n").unwrap().as_usize().unwrap();
        let g = rec.get("g").unwrap().as_usize().unwrap();
        let wng = Wng::new(w, n, g);
        let t = wng.t_in();
        assert_eq!(t, rec.get("t_in").unwrap().as_usize().unwrap(), "({w},{n},{g})");

        let ds = wng.descriptors();
        let branch = rec.get("branch").unwrap().i32_vec().unwrap();
        let row = rec.get("row").unwrap().i32_vec().unwrap();
        let col = rec.get("col").unwrap().i32_vec().unwrap();
        let relpos = rec.get("relpos").unwrap().i32_vec().unwrap();
        for i in 0..t {
            assert_eq!(ds[i].branch as i32, branch[i], "branch[{i}] ({w},{n},{g})");
            assert_eq!(ds[i].row as i32, row[i], "row[{i}] ({w},{n},{g})");
            assert_eq!(ds[i].col as i32, col[i], "col[{i}] ({w},{n},{g})");
            assert_eq!(ds[i].relpos as i32, relpos[i], "relpos[{i}] ({w},{n},{g})");
        }

        let mask = wng.intra_mask();
        let rows = rec.get("mask_rows").unwrap().str_vec().unwrap();
        for (qi, bits) in rows.iter().enumerate() {
            for (ki, ch) in bits.chars().enumerate() {
                let want = ch == '1';
                let got = mask[qi * t + ki] == 1;
                assert_eq!(got, want, "mask[{qi},{ki}] ({w},{n},{g})");
            }
        }
    }
}
