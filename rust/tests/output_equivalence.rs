//! Paper Appendix E: every exact engine must reproduce the autoregressive
//! greedy output byte-for-byte (lookahead specialized/generic/pallas,
//! speculative decoding, prompt lookup, jacobi). This is the lossless-ness
//! claim of the whole paper, verified end-to-end through the real
//! PJRT runtime and AOT artifacts.

use lookahead::engine::autoregressive::AutoRegressive;
use lookahead::engine::jacobi::Jacobi;
use lookahead::engine::lookahead::{Lookahead, LookaheadConfig};
use lookahead::engine::prompt_lookup::PromptLookup;
use lookahead::engine::spec_decode::SpecDecode;
use lookahead::engine::{Decoder, GenParams};
use lookahead::runtime::{cpu_client, Manifest, ModelRuntime};
use lookahead::tokenizer::ByteTokenizer;

/// Skip (returning true) when the AOT artifacts are not built.
fn no_artifacts() -> bool {
    lookahead::bench::skip_without_artifacts(module_path!())
}

fn setup() -> (Manifest, ModelRuntime) {
    let manifest = Manifest::load("artifacts").expect("run `make artifacts` first");
    let client = cpu_client().unwrap();
    let rt = ModelRuntime::load(&client, &manifest, "tiny").unwrap();
    (manifest, rt)
}

fn prompts() -> Vec<Vec<u32>> {
    let tok = ByteTokenizer::new();
    [
        "def add_ab(a, b):\n    result = a",
        "user: how does the warm cache work with the token?\n",
        "Q: what is 12 + 34?\n",
        "class QueueCache:\n    def __init__(self, size):\n",
    ]
    .iter()
    .map(|p| tok.encode_with_bos(p))
    .collect()
}

fn run(engine: &mut dyn Decoder, rt: &ModelRuntime, prompt: &[u32]) -> Vec<u32> {
    let params = GenParams { max_new_tokens: 48, ..Default::default() };
    engine.generate(rt, prompt, &params).unwrap().tokens
}

#[test]
fn lookahead_specialized_matches_autoregressive() {
    if no_artifacts() {
        return;
    }
    let (_, rt) = setup();
    let mut ar = AutoRegressive::new();
    let mut la = Lookahead::with_wng(5, 3, 5);
    for p in prompts() {
        let want = run(&mut ar, &rt, &p);
        let got = run(&mut la, &rt, &p);
        assert_eq!(got, want, "lookahead diverged from AR");
    }
}

#[test]
fn lookahead_pallas_matches_autoregressive() {
    if no_artifacts() {
        return;
    }
    let (_, rt) = setup();
    let mut ar = AutoRegressive::new();
    let mut cfg = LookaheadConfig::new(5, 3, 5);
    cfg.attn = "pallas".into();
    let mut la = Lookahead::new(cfg);
    for p in prompts().into_iter().take(2) {
        let want = run(&mut ar, &rt, &p);
        let got = run(&mut la, &rt, &p);
        assert_eq!(got, want, "pallas lookahead diverged from AR");
    }
}

#[test]
fn lookahead_generic_matches_autoregressive() {
    if no_artifacts() {
        return;
    }
    let (_, rt) = setup();
    let mut ar = AutoRegressive::new();
    let mut cfg = LookaheadConfig::new(4, 3, 4); // no specialized artifact
    cfg.force_generic = true;
    let mut la = Lookahead::new(cfg);
    for p in prompts().into_iter().take(2) {
        let want = run(&mut ar, &rt, &p);
        let got = run(&mut la, &rt, &p);
        assert_eq!(got, want, "generic lookahead diverged from AR");
    }
}

#[test]
fn lookahead_without_prompt_ref_matches_autoregressive() {
    if no_artifacts() {
        return;
    }
    let (_, rt) = setup();
    let mut ar = AutoRegressive::new();
    let mut cfg = LookaheadConfig::new(5, 3, 5);
    cfg.prompt_as_ref = false;
    let mut la = Lookahead::new(cfg);
    let p = &prompts()[0];
    assert_eq!(run(&mut la, &rt, p), run(&mut ar, &rt, p));
}

#[test]
fn spec_decode_matches_autoregressive() {
    if no_artifacts() {
        return;
    }
    let (manifest, rt) = setup();
    let draft = ModelRuntime::load(&rt.client, &manifest, "draft").unwrap();
    let mut ar = AutoRegressive::new();
    let mut sd = SpecDecode::new(draft, 4);
    for p in prompts().into_iter().take(2) {
        let want = run(&mut ar, &rt, &p);
        let got = run(&mut sd, &rt, &p);
        assert_eq!(got, want, "spec_decode diverged from AR");
    }
}

#[test]
fn prompt_lookup_matches_autoregressive() {
    if no_artifacts() {
        return;
    }
    let (_, rt) = setup();
    let mut ar = AutoRegressive::new();
    let mut pl = PromptLookup::new(8, 1);
    for p in prompts().into_iter().take(2) {
        let want = run(&mut ar, &rt, &p);
        let got = run(&mut pl, &rt, &p);
        assert_eq!(got, want, "prompt_lookup diverged from AR");
    }
}

#[test]
fn jacobi_matches_autoregressive() {
    if no_artifacts() {
        return;
    }
    let (_, rt) = setup();
    let mut ar = AutoRegressive::new();
    let mut j = Jacobi::new(8);
    let p = &prompts()[0];
    assert_eq!(run(&mut j, &rt, p), run(&mut ar, &rt, p), "jacobi diverged");
}

#[test]
fn lookahead_compresses_steps() {
    if no_artifacts() {
        return;
    }
    // the headline property: S > 1 on a predictable (code) prompt
    let (_, rt) = setup();
    let tok = ByteTokenizer::new();
    let p = tok.encode_with_bos("def add_ab(a, b):\n    result = a + b\n    return result\n\ndef add_xy(x, y):\n    result = x");
    let mut la = Lookahead::with_wng(5, 3, 5);
    let params = GenParams { max_new_tokens: 64, ..Default::default() };
    let out = la.generate(&rt, &p, &params).unwrap();
    let s = out.stats.compression();
    assert!(s > 1.2, "expected step compression > 1.2, got {s:.2} \
                      ({} tokens / {} steps)", out.stats.generated_tokens,
            out.stats.decode_steps);
}
