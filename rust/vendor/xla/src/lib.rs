//! Offline stub of the `xla` PJRT bindings.
//!
//! The serving coordinator (`rust/src/runtime/`) talks to PJRT through this
//! crate's API. The real build links the patched xla-rs bindings (native
//! PJRT CPU plugin + `untuple_result` patch); this stub reproduces the exact
//! API surface the coordinator uses so the whole workspace compiles, lints,
//! and unit-tests on machines without the PJRT toolchain. Every runtime
//! entry point returns [`Error`] — integration tests and benches that need
//! real artifacts gate on `artifacts/manifest.json` and skip cleanly.
//!
//! Keep this file in sync with the call sites in `rust/src/runtime/model.rs`
//! and `rust/src/runtime/client.rs`; it intentionally contains nothing more.

use std::fmt;
use std::path::Path;

/// Error type mirroring xla-rs: a display-able wrapper the coordinator maps
/// into `anyhow` contexts.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT runtime unavailable (stub `xla` crate; build against \
         the real xla-rs bindings to execute models)"
    )))
}

/// Element types the coordinator passes for raw-byte host buffers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    U8,
    S32,
    S64,
    F32,
    F64,
}

/// Host types accepted by `buffer_from_host_buffer` / `Literal::to_vec`.
pub trait NativeType: Copy {}

impl NativeType for u8 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for f32 {}
impl NativeType for f64 {}

/// A PJRT device handle (never materialized by the stub; present so
/// `Option<&PjRtDevice>` arguments type-check).
#[derive(Debug)]
pub struct PjRtDevice;

/// A PJRT client. Not `Send` in the real bindings — the coordinator keeps
/// one per worker thread; the stub mirrors that by holding a `Rc`-like
/// non-Send marker.
#[derive(Clone)]
pub struct PjRtClient {
    _not_send: std::marker::PhantomData<std::rc::Rc<()>>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }

    pub fn buffer_from_host_raw_bytes(
        &self,
        _ty: ElementType,
        _bytes: &[u8],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_raw_bytes")
    }
}

/// A device-resident buffer.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Host-side literal produced by `to_literal_sync`.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Bulk weight loading from `.npz` archives (trait form mirrors xla-rs).
pub trait FromRawBytes: Sized {
    fn read_npz_by_name(
        path: impl AsRef<Path>,
        client: &PjRtClient,
        names: &[&str],
    ) -> Result<Vec<Self>>;
}

impl FromRawBytes for PjRtBuffer {
    fn read_npz_by_name(
        path: impl AsRef<Path>,
        _client: &PjRtClient,
        _names: &[&str],
    ) -> Result<Vec<PjRtBuffer>> {
        unavailable(&format!(
            "PjRtBuffer::read_npz_by_name({:?})",
            path.as_ref()
        ))
    }
}

/// A compiled-and-loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with borrowed buffer arguments; outer Vec is per-device.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// Parsed HLO module text.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        unavailable(&format!("HloModuleProto::from_text_file({:?})", path.as_ref()))
    }
}

/// An XLA computation wrapping a parsed HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("PJRT runtime unavailable"));
    }

    #[test]
    fn error_is_std_error() {
        fn takes_err(_e: &dyn std::error::Error) {}
        takes_err(&Error("x".into()));
    }
}
