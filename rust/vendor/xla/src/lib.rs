//! Offline stub of the `xla` PJRT bindings — now with a deterministic
//! simulation backend.
//!
//! The serving coordinator (`rust/src/runtime/`) talks to PJRT through this
//! crate's API. The real build links the patched xla-rs bindings (native
//! PJRT CPU plugin + `untuple_result` patch). This stub reproduces the exact
//! API surface the coordinator uses so the whole workspace compiles, lints,
//! and tests on machines without the PJRT toolchain — and it can *execute*
//! a small class of artifacts: HLO text files whose first line is a
//! `sim <kind> key=value ...` directive (written by
//! `lookahead::runtime::sim::write_sim_artifacts`). Real HLO text still
//! fails with the historical "PJRT runtime unavailable" error at compile
//! time, so integration tests that need real artifacts keep gating on
//! `artifacts/manifest.json` and skipping cleanly.
//!
//! ## The simulated model
//!
//! The sim implements a *deterministic causal language model* over token-id
//! sequences, with the same calling convention as the AOT-lowered
//! executables (see `rust/src/runtime/manifest.rs` for parameter order):
//!
//! - a KV-cache row holds exactly the token id committed at that absolute
//!   position (junk rows hold -1);
//! - the logits for a query are a pure function of the ordered sequence of
//!   `(absolute position, token)` pairs the query attends to: the committed
//!   prefix (cache rows `0..cache_len`), then the intra-step tokens visible
//!   under the causal chain (linear order for `decode_lin`, the caller's
//!   mask/relpos for `decode_gen`, position = `cache_len + relpos`);
//! - the argmax token follows short predictable ramps with occasional
//!   hash-driven jumps and rare EOS emissions, so speculation (n-gram pools,
//!   draft models, Jacobi fixed points) gets realistic accept lengths while
//!   every engine's greedy output stays byte-exact with autoregressive
//!   decoding.
//!
//! Because the logits depend only on the attended `(position, token)`
//! sequence, batched executables (`decode_lin_b` / `decode_gen_b`) are
//! bit-identical to running their per-session base executable once per
//! slot — the invariant the batched-vs-sequential equivalence suite pins.
//!
//! Directive grammar (first whitespace-separated line of the .hlo.txt file):
//!
//!   sim prefill      plen=P rows=S vocab=V weights=K
//!   sim decode_lin   k=T vocab=V weights=K [delay_ms=D]
//!   sim decode_gen   t_pad=T vocab=V weights=K [delay_ms=D]
//!   sim decode_lin_b k=T batch=B vocab=V weights=K [delay_ms=D]
//!   sim decode_gen_b t_pad=T batch=B vocab=V weights=K [delay_ms=D]
//!   sim commit       slots=C
//!   sim cache_io     rows=S
//!
//! `cache_io` is the device<->host serialization hook for the KV-cache
//! manager (`rust/src/kv/`): called with a cache buffer it returns the raw
//! rows as `i32[rows]` (download); called with an `i32[rows]` buffer it
//! returns a fresh cache holding those rows (upload). A real-PJRT lowering
//! of the same contract is a pair of identity/convert programs over the
//! cache tensor.
//!
//! `delay_ms` makes each decode *launch* sleep (once per call, batched or
//! not — modeling the fused-call economics); serving tests use it to open
//! deterministic windows for cancellation/deadline races.
//!
//! Keep this file in sync with the call sites in `rust/src/runtime/model.rs`
//! and `rust/src/runtime/client.rs`.

use std::fmt;
use std::path::Path;

/// Error type mirroring xla-rs: a display-able wrapper the coordinator maps
/// into `anyhow` contexts.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT runtime unavailable (stub `xla` crate executes only \
         `sim` directives; build against the real xla-rs bindings to run \
         AOT-lowered models)"
    )))
}

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error(msg.into()))
}

/// Element types the coordinator passes for raw-byte host buffers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    U8,
    S32,
    S64,
    F32,
    F64,
}

// ---------------------------------------------------------------------------
// buffers
// ---------------------------------------------------------------------------

/// What a simulated device buffer holds.
#[derive(Debug, Clone)]
enum Payload {
    I32(Vec<i32>),
    U8(Vec<u8>),
    F32(Vec<f32>),
    /// KV cache: token id per committed row, -1 for junk rows.
    Cache(Vec<i32>),
    /// Per-step new KV: the step-input token per slot.
    NewKv(Vec<i32>),
    /// Weight placeholder (the sim model is weight-free).
    Weight,
    /// Wide host types kept lossless so a future i64/f64 call site fails
    /// with a type mismatch instead of silently truncating through i32/f32
    /// (the sim executables only consume I32/U8/F32 today).
    I64(Vec<i64>),
    F64(Vec<f64>),
}

/// Host types accepted by `buffer_from_host_buffer` / `Literal::to_vec`.
pub trait NativeType: Copy {
    fn to_payload(data: &[Self]) -> Payload;
    fn from_payload(p: &Payload) -> Option<Vec<Self>>;
}

impl NativeType for i32 {
    fn to_payload(data: &[Self]) -> Payload {
        Payload::I32(data.to_vec())
    }
    fn from_payload(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for u8 {
    fn to_payload(data: &[Self]) -> Payload {
        Payload::U8(data.to_vec())
    }
    fn from_payload(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::U8(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for f32 {
    fn to_payload(data: &[Self]) -> Payload {
        Payload::F32(data.to_vec())
    }
    fn from_payload(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for u32 {
    fn to_payload(data: &[Self]) -> Payload {
        Payload::I32(data.iter().map(|&x| x as i32).collect())
    }
    fn from_payload(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::I32(v) => Some(v.iter().map(|&x| x as u32).collect()),
            _ => None,
        }
    }
}

impl NativeType for i64 {
    fn to_payload(data: &[Self]) -> Payload {
        Payload::I64(data.to_vec())
    }
    fn from_payload(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::I64(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for f64 {
    fn to_payload(data: &[Self]) -> Payload {
        Payload::F64(data.to_vec())
    }
    fn from_payload(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::F64(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A PJRT device handle (present so `Option<&PjRtDevice>` arguments
/// type-check; the sim ignores device placement).
#[derive(Debug)]
pub struct PjRtDevice;

/// A PJRT client. Not `Send` in the real bindings — the coordinator keeps
/// one per worker thread; the stub mirrors that by holding a `Rc`-like
/// non-Send marker.
#[derive(Clone)]
pub struct PjRtClient {
    _not_send: std::marker::PhantomData<std::rc::Rc<()>>,
}

impl PjRtClient {
    /// The sim client always constructs; whether anything can *execute* is
    /// decided per-executable at compile time (sim directive vs real HLO).
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _not_send: std::marker::PhantomData })
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match SimExe::parse(&comp.text) {
            Some(exe) => Ok(PjRtLoadedExecutable { exe }),
            None => unavailable("PjRtClient::compile(non-sim HLO)"),
        }
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer { payload: T::to_payload(data) })
    }

    pub fn buffer_from_host_raw_bytes(
        &self,
        ty: ElementType,
        bytes: &[u8],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        match ty {
            ElementType::U8 | ElementType::Pred => {
                Ok(PjRtBuffer { payload: Payload::U8(bytes.to_vec()) })
            }
            other => err(format!("buffer_from_host_raw_bytes: unsupported {other:?}")),
        }
    }
}

/// A device-resident buffer.
pub struct PjRtBuffer {
    payload: Payload,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(Literal { payload: self.payload.clone() })
    }
}

/// Host-side literal produced by `to_literal_sync`.
pub struct Literal {
    payload: Payload,
}

impl Literal {
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_payload(&self.payload)
            .ok_or_else(|| Error("Literal::to_vec: payload type mismatch".into()))
    }
}

/// Bulk weight loading from `.npz` archives (trait form mirrors xla-rs).
/// The sim accepts weight files starting with the `SIM` magic and returns
/// one placeholder buffer per requested name (the sim model is weight-free).
pub trait FromRawBytes: Sized {
    fn read_npz_by_name(
        path: impl AsRef<Path>,
        client: &PjRtClient,
        names: &[&str],
    ) -> Result<Vec<Self>>;
}

impl FromRawBytes for PjRtBuffer {
    fn read_npz_by_name(
        path: impl AsRef<Path>,
        _client: &PjRtClient,
        names: &[&str],
    ) -> Result<Vec<PjRtBuffer>> {
        let path = path.as_ref();
        let head = std::fs::read(path)
            .map_err(|e| Error(format!("read_npz_by_name({path:?}): {e}")))?;
        if head.starts_with(b"SIM") {
            return Ok(names
                .iter()
                .map(|_| PjRtBuffer { payload: Payload::Weight })
                .collect());
        }
        unavailable(&format!("PjRtBuffer::read_npz_by_name({path:?}): real npz"))
    }
}

/// Parsed HLO module text.
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("HloModuleProto::from_text_file({path:?}): {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation wrapping a parsed HLO module.
pub struct XlaComputation {
    text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { text: proto.text.clone() }
    }
}

// ---------------------------------------------------------------------------
// the simulated executables
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq, Eq)]
enum SimKind {
    Prefill,
    DecodeLin,
    DecodeGen,
    DecodeLinB,
    DecodeGenB,
    Commit,
    CacheIo,
}

#[derive(Debug, Clone)]
struct SimExe {
    kind: SimKind,
    /// step-input tokens per slot (plen for prefill, k / t_pad for decode).
    t: usize,
    /// cache rows (prefill only — decode infers from the incoming cache).
    rows: usize,
    vocab: usize,
    weights: usize,
    batch: usize,
    slots: usize,
    delay_ms: u64,
}

impl SimExe {
    /// Parse the `sim <kind> key=value ...` directive; None for real HLO.
    fn parse(text: &str) -> Option<SimExe> {
        let line = text.lines().next()?.trim();
        let mut it = line.split_whitespace();
        if it.next()? != "sim" {
            return None;
        }
        let kind = match it.next()? {
            "prefill" => SimKind::Prefill,
            "decode_lin" => SimKind::DecodeLin,
            "decode_gen" => SimKind::DecodeGen,
            "decode_lin_b" => SimKind::DecodeLinB,
            "decode_gen_b" => SimKind::DecodeGenB,
            "commit" => SimKind::Commit,
            "cache_io" => SimKind::CacheIo,
            _ => return None,
        };
        let mut exe = SimExe {
            kind,
            t: 0,
            rows: 0,
            vocab: 0,
            weights: 0,
            batch: 1,
            slots: 0,
            delay_ms: 0,
        };
        for kv in it {
            let (k, v) = kv.split_once('=')?;
            let v: usize = v.parse().ok()?;
            match k {
                "plen" | "k" | "t_pad" => exe.t = v,
                "rows" => exe.rows = v,
                "vocab" => exe.vocab = v,
                "weights" => exe.weights = v,
                "batch" => exe.batch = v,
                "slots" => exe.slots = v,
                "delay_ms" => exe.delay_ms = v as u64,
                _ => return None,
            }
        }
        Some(exe)
    }
}

// -- the deterministic LM ---------------------------------------------------

/// Order-sensitive fold of one `(position, token)` pair into the running
/// prefix hash (splitmix64-style finalizer).
fn mix(h: u64, pos: i64, tok: i64) -> u64 {
    let mut x = h
        ^ (pos as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (tok as u64).wrapping_add(1).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    x
}

/// EOS token id of the byte tokenizer (`rust/src/tokenizer`): the sim emits
/// it rarely so finish-by-EOS paths get exercised.
const SIM_EOS: i64 = 258;

/// The sim LM's next token given the prefix hash and the last attended
/// token: short +1 ramps (speculation-friendly), occasional hash jumps,
/// rare EOS. Always < 259 (the live vocab).
fn sim_next_token(h: u64, last: i64) -> i64 {
    if h % 41 == 0 {
        SIM_EOS
    } else if h % 5 == 0 {
        ((h >> 16) % 251) as i64
    } else {
        (last.max(0) + 1) % 251
    }
}

/// Deterministic logits row: every id gets noise in [0, 1); the sim LM's
/// chosen next token gets 2.0 so greedy argmax (over the live vocab, which
/// always contains it) recovers `sim_next_token` exactly.
fn sim_logits_row(h: u64, last: i64, vocab: usize, out: &mut Vec<f32>) {
    let next = sim_next_token(h, last);
    for v in 0..vocab {
        let n = mix(h ^ 0xA5A5_5A5A_DEAD_BEEF, v as i64, 1);
        out.push((n % 1024) as f32 / 1024.0);
    }
    let base = out.len() - vocab;
    out[base + next as usize] = 2.0;
}

/// Fold the committed prefix (cache rows `0..cache_len`) into a hash.
fn fold_prefix(cache: &[i32], cache_len: usize) -> (u64, i64) {
    let mut h = 0x5EED_u64;
    let mut last = -1i64;
    for (p, &t) in cache.iter().take(cache_len).enumerate() {
        h = mix(h, p as i64, t as i64);
        last = t as i64;
    }
    (h, last)
}

// -- argument plumbing ------------------------------------------------------

fn arg_i32(args: &[&PjRtBuffer], i: usize, what: &str) -> Result<Vec<i32>> {
    match args.get(i).map(|b| &b.payload) {
        Some(Payload::I32(v)) => Ok(v.clone()),
        other => err(format!("sim: arg {i} ({what}) must be i32, got {other:?}")),
    }
}

fn arg_scalar(args: &[&PjRtBuffer], i: usize, what: &str) -> Result<i32> {
    let v = arg_i32(args, i, what)?;
    v.first()
        .copied()
        .ok_or_else(|| Error(format!("sim: arg {i} ({what}) is empty")))
}

fn arg_u8(args: &[&PjRtBuffer], i: usize, what: &str) -> Result<Vec<u8>> {
    match args.get(i).map(|b| &b.payload) {
        Some(Payload::U8(v)) => Ok(v.clone()),
        other => err(format!("sim: arg {i} ({what}) must be u8, got {other:?}")),
    }
}

fn arg_cache(args: &[&PjRtBuffer], i: usize) -> Result<Vec<i32>> {
    match args.get(i).map(|b| &b.payload) {
        Some(Payload::Cache(v)) => Ok(v.clone()),
        other => err(format!("sim: arg {i} (cache) must be a cache, got {other:?}")),
    }
}

fn arg_newkv(args: &[&PjRtBuffer], i: usize) -> Result<Vec<i32>> {
    match args.get(i).map(|b| &b.payload) {
        Some(Payload::NewKv(v)) => Ok(v.clone()),
        other => err(format!("sim: arg {i} (new_kv) must be new_kv, got {other:?}")),
    }
}

fn buf(p: Payload) -> PjRtBuffer {
    PjRtBuffer { payload: p }
}

/// A compiled-and-loaded executable.
pub struct PjRtLoadedExecutable {
    exe: SimExe,
}

impl PjRtLoadedExecutable {
    /// Execute with borrowed buffer arguments; outer Vec is per-device.
    pub fn execute_b(&self, args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        if self.exe.delay_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(self.exe.delay_ms));
        }
        let out = match self.exe.kind {
            SimKind::Prefill => self.run_prefill(args)?,
            SimKind::DecodeLin => self.run_decode_lin(args)?,
            SimKind::DecodeGen => self.run_decode_gen(args)?,
            SimKind::DecodeLinB => self.run_decode_lin_b(args)?,
            SimKind::DecodeGenB => self.run_decode_gen_b(args)?,
            SimKind::Commit => self.run_commit(args)?,
            SimKind::CacheIo => self.run_cache_io(args)?,
        };
        Ok(vec![out])
    }

    /// cache_io: one arg, direction decided by its payload.
    ///   [cache]        -> [i32[rows]]  (download: raw committed rows)
    ///   [i32[rows]]    -> [cache]      (upload: rebuild a device cache)
    fn run_cache_io(&self, args: &[&PjRtBuffer]) -> Result<Vec<PjRtBuffer>> {
        let rows = self.exe.rows;
        if args.len() != 1 {
            return err(format!("sim cache_io: want 1 arg, got {}", args.len()));
        }
        match &args[0].payload {
            Payload::Cache(v) => {
                if v.len() != rows {
                    return err(format!("sim cache_io: cache has {} rows, want {rows}",
                                       v.len()));
                }
                Ok(vec![buf(Payload::I32(v.clone()))])
            }
            Payload::I32(v) => {
                if v.len() != rows {
                    return err(format!("sim cache_io: data has {} rows, want {rows}",
                                       v.len()));
                }
                Ok(vec![buf(Payload::Cache(v.clone()))])
            }
            other => err(format!("sim cache_io: arg must be cache or i32, got {other:?}")),
        }
    }

    /// prefill: weights.., tokens i32[plen], n_valid -> [logits, cache]
    fn run_prefill(&self, args: &[&PjRtBuffer]) -> Result<Vec<PjRtBuffer>> {
        let SimExe { t: plen, rows, vocab, weights, .. } = self.exe;
        if args.len() != weights + 2 {
            return err(format!("sim prefill: want {} args, got {}", weights + 2,
                               args.len()));
        }
        let tokens = arg_i32(args, weights, "tokens")?;
        let n_valid = arg_scalar(args, weights + 1, "n_valid")? as usize;
        if tokens.len() != plen || n_valid > plen {
            return err(format!("sim prefill: tokens {}/{} n_valid {}",
                               tokens.len(), plen, n_valid));
        }
        let mut logits = Vec::with_capacity(plen * vocab);
        let mut h = 0x5EED_u64;
        for (p, &tok) in tokens.iter().enumerate() {
            h = mix(h, p as i64, tok as i64);
            sim_logits_row(h, tok as i64, vocab, &mut logits);
        }
        let mut cache = vec![-1i32; rows];
        cache[..n_valid].copy_from_slice(&tokens[..n_valid]);
        Ok(vec![buf(Payload::F32(logits)), buf(Payload::Cache(cache))])
    }

    /// One linear-chain slot: logits for `tokens` given `cache[0..cache_len]`.
    fn lin_slot(&self, cache: &[i32], cache_len: usize, tokens: &[i32],
                logits: &mut Vec<f32>) {
        let (mut h, _) = fold_prefix(cache, cache_len);
        for (j, &tok) in tokens.iter().enumerate() {
            h = mix(h, (cache_len + j) as i64, tok as i64);
            sim_logits_row(h, tok as i64, self.exe.vocab, logits);
        }
    }

    /// decode_lin: weights.., cache, cache_len, tokens i32[k]
    /// -> [logits, new_kv]
    fn run_decode_lin(&self, args: &[&PjRtBuffer]) -> Result<Vec<PjRtBuffer>> {
        let SimExe { t: k, weights, .. } = self.exe;
        if args.len() != weights + 3 {
            return err(format!("sim decode_lin: want {} args, got {}",
                               weights + 3, args.len()));
        }
        let cache = arg_cache(args, weights)?;
        let cache_len = arg_scalar(args, weights + 1, "cache_len")? as usize;
        let tokens = arg_i32(args, weights + 2, "tokens")?;
        if tokens.len() != k || cache_len > cache.len() {
            return err(format!("sim decode_lin: tokens {}/{k} cache_len {}",
                               tokens.len(), cache_len));
        }
        let mut logits = Vec::with_capacity(k * self.exe.vocab);
        self.lin_slot(&cache, cache_len, &tokens, &mut logits);
        Ok(vec![buf(Payload::F32(logits)), buf(Payload::NewKv(tokens))])
    }

    /// One masked slot: logits for `tokens` under (relpos, mask) given
    /// `cache[0..cache_len]`. Query q attends to the committed prefix plus
    /// every intra-step slot its mask row admits, ordered by (relpos, slot).
    fn gen_slot(&self, cache: &[i32], cache_len: usize, tokens: &[i32],
                relpos: &[i32], mask: &[u8], logits: &mut Vec<f32>) {
        let t = self.exe.t;
        let (h0, last0) = fold_prefix(cache, cache_len);
        for q in 0..t {
            let mut vis: Vec<usize> =
                (0..t).filter(|&j| mask[q * t + j] != 0).collect();
            vis.sort_by_key(|&j| (relpos[j], j));
            let mut h = h0;
            let mut last = last0;
            for &j in &vis {
                h = mix(h, cache_len as i64 + relpos[j] as i64, tokens[j] as i64);
                last = tokens[j] as i64;
            }
            sim_logits_row(h, last, self.exe.vocab, logits);
        }
    }

    /// decode_gen: weights.., cache, cache_len, tokens i32[t_pad],
    /// relpos i32[t_pad], mask u8[t_pad*t_pad] -> [logits, new_kv]
    fn run_decode_gen(&self, args: &[&PjRtBuffer]) -> Result<Vec<PjRtBuffer>> {
        let SimExe { t, weights, .. } = self.exe;
        if args.len() != weights + 5 {
            return err(format!("sim decode_gen: want {} args, got {}",
                               weights + 5, args.len()));
        }
        let cache = arg_cache(args, weights)?;
        let cache_len = arg_scalar(args, weights + 1, "cache_len")? as usize;
        let tokens = arg_i32(args, weights + 2, "tokens")?;
        let relpos = arg_i32(args, weights + 3, "relpos")?;
        let mask = arg_u8(args, weights + 4, "mask")?;
        if tokens.len() != t || relpos.len() != t || mask.len() != t * t
            || cache_len > cache.len()
        {
            return err("sim decode_gen: arg shapes wrong");
        }
        let mut logits = Vec::with_capacity(t * self.exe.vocab);
        self.gen_slot(&cache, cache_len, &tokens, &relpos, &mask, &mut logits);
        Ok(vec![buf(Payload::F32(logits)), buf(Payload::NewKv(tokens))])
    }

    /// decode_lin_b: weights.., cache_0..cache_{B-1}, cache_lens i32[B],
    /// tokens i32[B*k] -> [logits f32[B*k*V], new_kv_0.., new_kv_{B-1}]
    fn run_decode_lin_b(&self, args: &[&PjRtBuffer]) -> Result<Vec<PjRtBuffer>> {
        let SimExe { t: k, weights, batch, .. } = self.exe;
        if args.len() != weights + batch + 2 {
            return err(format!("sim decode_lin_b: want {} args, got {}",
                               weights + batch + 2, args.len()));
        }
        let lens = arg_i32(args, weights + batch, "cache_lens")?;
        let tokens = arg_i32(args, weights + batch + 1, "tokens")?;
        if lens.len() != batch || tokens.len() != batch * k {
            return err("sim decode_lin_b: arg shapes wrong");
        }
        let mut logits = Vec::with_capacity(batch * k * self.exe.vocab);
        let mut outs = Vec::with_capacity(1 + batch);
        outs.push(buf(Payload::F32(Vec::new()))); // placeholder, filled below
        for b in 0..batch {
            let cache = arg_cache(args, weights + b)?;
            let cache_len = lens[b] as usize;
            if cache_len > cache.len() {
                return err(format!("sim decode_lin_b: slot {b} cache_len"));
            }
            let slot = &tokens[b * k..(b + 1) * k];
            self.lin_slot(&cache, cache_len, slot, &mut logits);
            outs.push(buf(Payload::NewKv(slot.to_vec())));
        }
        outs[0] = buf(Payload::F32(logits));
        Ok(outs)
    }

    /// decode_gen_b: weights.., cache_0..cache_{B-1}, cache_lens i32[B],
    /// tokens i32[B*t_pad], relpos i32[t_pad], mask u8[t_pad*t_pad]
    /// (relpos/mask shared — batched groups share one engine config)
    /// -> [logits f32[B*t_pad*V], new_kv_0.., new_kv_{B-1}]
    fn run_decode_gen_b(&self, args: &[&PjRtBuffer]) -> Result<Vec<PjRtBuffer>> {
        let SimExe { t, weights, batch, .. } = self.exe;
        if args.len() != weights + batch + 4 {
            return err(format!("sim decode_gen_b: want {} args, got {}",
                               weights + batch + 4, args.len()));
        }
        let lens = arg_i32(args, weights + batch, "cache_lens")?;
        let tokens = arg_i32(args, weights + batch + 1, "tokens")?;
        let relpos = arg_i32(args, weights + batch + 2, "relpos")?;
        let mask = arg_u8(args, weights + batch + 3, "mask")?;
        if lens.len() != batch || tokens.len() != batch * t || relpos.len() != t
            || mask.len() != t * t
        {
            return err("sim decode_gen_b: arg shapes wrong");
        }
        let mut logits = Vec::with_capacity(batch * t * self.exe.vocab);
        let mut outs = Vec::with_capacity(1 + batch);
        outs.push(buf(Payload::F32(Vec::new())));
        for b in 0..batch {
            let cache = arg_cache(args, weights + b)?;
            let cache_len = lens[b] as usize;
            if cache_len > cache.len() {
                return err(format!("sim decode_gen_b: slot {b} cache_len"));
            }
            let slot = &tokens[b * t..(b + 1) * t];
            self.gen_slot(&cache, cache_len, slot, &relpos, &mask, &mut logits);
            outs.push(buf(Payload::NewKv(slot.to_vec())));
        }
        outs[0] = buf(Payload::F32(logits));
        Ok(outs)
    }

    /// commit: cache, new_kv, src_idx i32[slots], dest_start, count
    /// -> [cache'] (scatter accepted new-KV rows into a fresh cache buffer)
    fn run_commit(&self, args: &[&PjRtBuffer]) -> Result<Vec<PjRtBuffer>> {
        if args.len() != 5 {
            return err(format!("sim commit: want 5 args, got {}", args.len()));
        }
        let mut cache = arg_cache(args, 0)?;
        let new_kv = arg_newkv(args, 1)?;
        let src_idx = arg_i32(args, 2, "src_idx")?;
        let dest_start = arg_scalar(args, 3, "dest_start")? as usize;
        let count = arg_scalar(args, 4, "count")? as usize;
        if count > src_idx.len() || dest_start + count > cache.len() {
            return err("sim commit: scatter out of range");
        }
        for k in 0..count {
            let src = src_idx[k] as usize;
            if src >= new_kv.len() {
                return err(format!("sim commit: src_idx[{k}]={src} out of range"));
            }
            cache[dest_start + k] = new_kv[src];
        }
        Ok(vec![buf(Payload::Cache(cache))])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn client() -> PjRtClient {
        PjRtClient::cpu().unwrap()
    }

    fn compile(directive: &str) -> PjRtLoadedExecutable {
        let comp = XlaComputation { text: directive.to_string() };
        client().compile(&comp).unwrap()
    }

    fn i32_buf(v: &[i32]) -> PjRtBuffer {
        client().buffer_from_host_buffer(v, &[v.len()], None).unwrap()
    }

    fn scalar(v: i32) -> PjRtBuffer {
        client().buffer_from_host_buffer(&[v], &[], None).unwrap()
    }

    fn weight() -> PjRtBuffer {
        buf(Payload::Weight)
    }

    fn f32s(b: &PjRtBuffer) -> Vec<f32> {
        b.to_literal_sync().unwrap().to_vec::<f32>().unwrap()
    }

    fn argmax(row: &[f32]) -> usize {
        let mut best = 0;
        for (i, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = i;
            }
        }
        best
    }

    const V: usize = 264;

    fn prefill_exe() -> PjRtLoadedExecutable {
        compile("sim prefill plen=8 rows=32 vocab=264 weights=1")
    }

    fn lin_exe(k: usize) -> PjRtLoadedExecutable {
        compile(&format!("sim decode_lin k={k} vocab=264 weights=1"))
    }

    /// Run prefill on `prompt` (padded to 8); returns (logits, cache).
    fn run_prefill(prompt: &[i32]) -> (Vec<f32>, PjRtBuffer) {
        let mut toks = prompt.to_vec();
        toks.resize(8, 256);
        let w = weight();
        let tb = i32_buf(&toks);
        let nv = scalar(prompt.len() as i32);
        let mut out = prefill_exe()
            .execute_b(&[&w, &tb, &nv])
            .unwrap()
            .remove(0);
        let cache = out.pop().unwrap();
        let logits = f32s(&out.pop().unwrap());
        (logits, cache)
    }

    #[test]
    fn compile_rejects_real_hlo_text() {
        let comp = XlaComputation { text: "HloModule real_thing".into() };
        let e = client().compile(&comp).err().unwrap();
        assert!(e.to_string().contains("PJRT runtime unavailable"));
    }

    #[test]
    fn directive_parsing_roundtrip() {
        let e = SimExe::parse("sim decode_gen_b t_pad=20 batch=8 vocab=264 weights=2")
            .unwrap();
        assert_eq!(e.kind, SimKind::DecodeGenB);
        assert_eq!((e.t, e.batch, e.vocab, e.weights), (20, 8, 264, 2));
        assert!(SimExe::parse("sim bogus x=1").is_none());
        assert!(SimExe::parse("").is_none());
    }

    #[test]
    fn error_is_std_error() {
        fn takes_err(_e: &dyn std::error::Error) {}
        takes_err(&Error("x".into()));
    }

    #[test]
    fn prefill_then_decode_lin_extends_the_same_lm() {
        // the LM invariant: decode after a committed prefix produces the
        // same next-token as the prefill row at the same prefix depth
        let prompt = [10i32, 11, 12, 13];
        let (logits, cache) = run_prefill(&prompt);
        let want = argmax(&logits[3 * V..4 * V]); // prefix = all 4 tokens

        // decode the last prompt token on top of cache_len = 3
        let w = weight();
        let cl = scalar(3);
        let tb = i32_buf(&[13]);
        let mut out = lin_exe(1).execute_b(&[&w, &cache, &cl, &tb]).unwrap().remove(0);
        let _kv = out.pop().unwrap();
        let dl = f32s(&out.pop().unwrap());
        assert_eq!(argmax(&dl[..V]), want, "decode_lin diverged from prefill");
    }

    #[test]
    fn lin_chain_matches_token_by_token() {
        // a k=3 chain row j must equal three successive k=1 calls
        let prompt = [5i32, 6];
        let (_, cache) = run_prefill(&prompt);
        let w = weight();
        let chain = [6i32, 7, 8];
        let cl = scalar(1);
        let tb = i32_buf(&chain);
        let mut out = lin_exe(3).execute_b(&[&w, &cache, &cl, &tb]).unwrap().remove(0);
        out.pop().unwrap();
        let big = f32s(&out.pop().unwrap());

        // k=1 replay: commit each token then decode the next
        let commit = compile("sim commit slots=4");
        let mut c = cache;
        for (j, &tok) in chain.iter().enumerate() {
            let cl = scalar((1 + j) as i32);
            let tb = i32_buf(&[tok]);
            let mut o = lin_exe(1).execute_b(&[&w, &c, &cl, &tb]).unwrap().remove(0);
            let kv = o.pop().unwrap();
            let row = f32s(&o.pop().unwrap());
            assert_eq!(row, big[j * V..(j + 1) * V].to_vec(),
                       "chain row {j} != sequential");
            let idx = i32_buf(&[0, 0, 0, 0]);
            let ds = scalar((1 + j) as i32);
            let cnt = scalar(1);
            let mut co = commit.execute_b(&[&c, &kv, &idx, &ds, &cnt]).unwrap()
                .remove(0);
            c = co.pop().unwrap();
        }
    }

    #[test]
    fn batched_lin_matches_per_slot_sequential() {
        let (_, cache_a) = run_prefill(&[1, 2, 3]);
        let (_, cache_b) = run_prefill(&[9, 8]);
        let w = weight();

        // sequential slots
        let mut seq = Vec::new();
        for (cache, len, tok) in [(&cache_a, 2, 3), (&cache_b, 1, 8)] {
            let cl = scalar(len);
            let tb = i32_buf(&[tok]);
            let mut o = lin_exe(1).execute_b(&[&w, cache, &cl, &tb]).unwrap().remove(0);
            o.pop().unwrap();
            seq.push(f32s(&o.pop().unwrap()));
        }

        // batched (batch=3: third slot is padding and must not disturb 0/1)
        let be = compile("sim decode_lin_b k=1 batch=3 vocab=264 weights=1");
        let lens = i32_buf(&[2, 1, 0]);
        let toks = i32_buf(&[3, 8, 256]);
        let mut out = be
            .execute_b(&[&w, &cache_a, &cache_b, &cache_a, &lens, &toks])
            .unwrap()
            .remove(0);
        assert_eq!(out.len(), 4, "logits + one new_kv per slot");
        let big = f32s(&out.remove(0));
        assert_eq!(big.len(), 3 * V);
        assert_eq!(big[..V].to_vec(), seq[0], "slot 0 diverged");
        assert_eq!(big[V..2 * V].to_vec(), seq[1], "slot 1 diverged");
    }

    #[test]
    fn batched_gen_matches_per_slot_sequential() {
        // 2-slot causal chain via the mask path: mask = lower triangle,
        // relpos = 0,1 — must equal decode_lin k=2 per slot.
        let (_, cache_a) = run_prefill(&[4, 5, 6]);
        let (_, cache_b) = run_prefill(&[7]);
        let w = weight();
        let relpos = i32_buf(&[0, 1]);
        let mask = client()
            .buffer_from_host_raw_bytes(ElementType::U8, &[1, 0, 1, 1], &[2, 2], None)
            .unwrap();

        let ge = compile("sim decode_gen t_pad=2 vocab=264 weights=1");
        let mut seq = Vec::new();
        for (cache, len, toks) in [(&cache_a, 2i32, [6, 20]), (&cache_b, 0, [7, 9])] {
            let cl = scalar(len);
            let tb = i32_buf(&toks);
            let mut o = ge
                .execute_b(&[&w, cache, &cl, &tb, &relpos, &mask])
                .unwrap()
                .remove(0);
            o.pop().unwrap();
            seq.push(f32s(&o.pop().unwrap()));
        }

        let gb = compile("sim decode_gen_b t_pad=2 batch=2 vocab=264 weights=1");
        let lens = i32_buf(&[2, 0]);
        let toks = i32_buf(&[6, 20, 7, 9]);
        let mut out = gb
            .execute_b(&[&w, &cache_a, &cache_b, &lens, &toks, &relpos, &mask])
            .unwrap()
            .remove(0);
        assert_eq!(out.len(), 3);
        let big = f32s(&out.remove(0));
        assert_eq!(big[..2 * V].to_vec(), seq[0], "slot 0 diverged");
        assert_eq!(big[2 * V..].to_vec(), seq[1], "slot 1 diverged");

        // the masked causal chain equals the linear chain
        let cl = scalar(2);
        let tb = i32_buf(&[6, 20]);
        let mut o = lin_exe(2).execute_b(&[&w, &cache_a, &cl, &tb]).unwrap().remove(0);
        o.pop().unwrap();
        assert_eq!(f32s(&o.pop().unwrap()), seq[0], "gen mask != lin chain");
    }

    #[test]
    fn commit_scatters_and_rejects_out_of_range() {
        let (_, cache) = run_prefill(&[1, 2, 3]);
        let kv = buf(Payload::NewKv(vec![40, 41, 42]));
        let commit = compile("sim commit slots=4");
        let idx = i32_buf(&[2, 0, 0, 0]);
        let ds = scalar(3);
        let cnt = scalar(2);
        let mut out = commit.execute_b(&[&cache, &kv, &idx, &ds, &cnt]).unwrap()
            .remove(0);
        let c = out.pop().unwrap();
        let rows = match &c.payload {
            Payload::Cache(r) => r.clone(),
            _ => panic!("commit must return a cache"),
        };
        assert_eq!(&rows[..5], &[1, 2, 3, 42, 40]);

        let bad_idx = i32_buf(&[9, 0, 0, 0]);
        assert!(commit.execute_b(&[&cache, &kv, &bad_idx, &ds, &cnt]).is_err());
    }

    #[test]
    fn shape_mismatches_surface_as_stub_errors() {
        // wrong arg count and wrong payload type must fail loudly so engine
        // tests never chase silent garbage
        let w = weight();
        assert!(lin_exe(1).execute_b(&[&w]).is_err());
        let not_cache = i32_buf(&[1, 2, 3]);
        let cl = scalar(0);
        let tb = i32_buf(&[1]);
        assert!(lin_exe(1).execute_b(&[&w, &not_cache, &cl, &tb]).is_err());
    }

    #[test]
    fn cache_io_roundtrips_and_validates() {
        let (_, cache) = run_prefill(&[1, 2, 3]);
        let io = compile("sim cache_io rows=32");
        // download: cache -> raw i32 rows
        let mut out = io.execute_b(&[&cache]).unwrap().remove(0);
        let rows = out.pop().unwrap().to_literal_sync().unwrap().to_vec::<i32>().unwrap();
        assert_eq!(rows.len(), 32);
        assert_eq!(&rows[..4], &[1, 2, 3, -1]);
        // upload: raw rows -> a cache that decodes identically
        let data = i32_buf(&rows);
        let mut out = io.execute_b(&[&data]).unwrap().remove(0);
        let rebuilt = out.pop().unwrap();
        let w = weight();
        let cl = scalar(2);
        let tb = i32_buf(&[3]);
        let a = lin_exe(1).execute_b(&[&w, &cache, &cl, &tb]).unwrap().remove(0);
        let b = lin_exe(1).execute_b(&[&w, &rebuilt, &cl, &tb]).unwrap().remove(0);
        assert_eq!(f32s(&a[0]), f32s(&b[0]), "rebuilt cache diverged");
        // wrong row count and wrong payload type fail loudly
        let short = i32_buf(&[1, 2, 3]);
        assert!(io.execute_b(&[&short]).is_err());
        assert!(io.execute_b(&[&w]).is_err());
    }

    #[test]
    fn weight_file_gate() {
        let dir = std::env::temp_dir().join(format!("xla-sim-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let sim = dir.join("w_sim.npz");
        std::fs::write(&sim, b"SIMWEIGHTS").unwrap();
        let bufs =
            PjRtBuffer::read_npz_by_name(&sim, &client(), &["a", "b"]).unwrap();
        assert_eq!(bufs.len(), 2);
        let real = dir.join("w_real.npz");
        std::fs::write(&real, b"PK\x03\x04").unwrap();
        assert!(PjRtBuffer::read_npz_by_name(&real, &client(), &["a"]).is_err());
    }
}
