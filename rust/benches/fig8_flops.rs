//! Fig. 8 — compression S and speedup vs the device's surplus-FLOPs budget
//! (paper: RTX 3090 vs A100, N = 5, FlashAttention on).
//!
//! S is device-independent (the blue/orange S curves overlap in the paper);
//! the *speedup* depends on how much free compute the device has. We measure
//! S on a W-sweep (N = 5, G = W) and project the speedup on both devices
//! with the DESIGN.md §7 latency model.
//!
//! Expected shape: identical S on both devices; A100 speedup keeps rising
//! with W while RTX3090 flattens/declines earlier (FLOPs cap bites).
//!
//!   cargo bench --bench fig8_flops [-- --quick]

use lookahead::analytic::{A100, RTX3090};
use lookahead::bench::driver::{run_suite_with, SuiteOptions};
use lookahead::bench::{bench_args, save_result, Table};
use lookahead::engine::lookahead::{Lookahead, LookaheadConfig};
use lookahead::runtime::load_model;
use lookahead::util::json::Json;
use lookahead::workload::Workloads;

fn main() -> anyhow::Result<()> {
    let args = bench_args();
    let quick = args.bool_or("quick", false);
    let (_, rt) = load_model("artifacts", "tiny")?;
    let workloads = Workloads::load("artifacts")?;
    let prompts = workloads.take("chat", if quick { 2 } else { 4 })?;
    let max_tokens = if quick { 32 } else { 64 };
    let n = 5usize;
    let ws: &[usize] = if quick { &[4, 15] } else { &[1, 2, 4, 8, 15, 30] };

    println!("Fig. 8: S (device-independent) and projected speedups, N = {n}, G = W, \
              chat suite, 7B-scale projection\n");
    let mut table = Table::new(&["W=G", "T_in", "S (measured)", "A100 speedup",
                                 "RTX3090 speedup", "cpu tok/s"]);
    let mut rows = Vec::new();
    for &w in ws {
        let t_in = 2 * w * (n - 1);
        if t_in > 256 {
            continue;
        }
        let mut cfg = LookaheadConfig::new(w, n, w);
        cfg.force_generic = true;
        let mut engine = Lookahead::new(cfg);
        let run = run_suite_with(&rt, &mut engine, &prompts,
                                 SuiteOptions::new(max_tokens))?.run;
        let a100 = run.projected(&A100, 7e9, t_in);
        let r3090 = run.projected(&RTX3090, 7e9, t_in);
        table.row(vec![
            w.to_string(),
            t_in.to_string(),
            format!("{:.3}", run.s()),
            format!("{a100:.2}x"),
            format!("{r3090:.2}x"),
            format!("{:.1}", run.tok_per_sec()),
        ]);
        rows.push(Json::obj(vec![
            ("w", Json::num(w as f64)),
            ("s", Json::num(run.s())),
            ("a100", Json::num(a100)),
            ("rtx3090", Json::num(r3090)),
        ]));
    }
    table.print();
    println!("\npaper expectation: >50% speedup easily on A100, ~30% on RTX3090; \
              the 3090 curve bends down first as the per-step FLOPs exceed its cap.");
    save_result("fig8_flops", Json::Arr(rows));
    Ok(())
}
