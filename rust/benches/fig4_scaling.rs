//! Fig. 4 — the scaling law of LOOKAHEAD DECODING.
//!
//! (a) measured: S over a (W, N) sweep with G = W on the chat suite
//!     (paper: LLaMA-2-Chat-7B on MT-Bench), via the generic executable.
//! (b) analytic: fit (alpha, f) to the measurements and print the Eq. 7
//!     curve next to them (paper uses alpha = 0.425, f = 3.106).
//!
//! Expected shape: S grows ~linearly in log(W*G) for fixed N until
//! saturation; larger N helps once W is large enough.
//!
//!   cargo bench --bench fig4_scaling [-- --quick]

use lookahead::analytic;
use lookahead::bench::driver::{run_suite_with, SuiteOptions};
use lookahead::bench::{bench_args, save_result, Table};
use lookahead::engine::lookahead::{Lookahead, LookaheadConfig};
use lookahead::runtime::load_model;
use lookahead::util::json::Json;
use lookahead::workload::Workloads;

fn main() -> anyhow::Result<()> {
    let args = bench_args();
    let quick = args.bool_or("quick", false);
    let (_, rt) = load_model("artifacts", "tiny")?;
    let workloads = Workloads::load("artifacts")?;
    let prompts = workloads.take("chat", if quick { 2 } else { 4 })?;
    let max_tokens = if quick { 32 } else { 64 };

    let ws: &[usize] = if quick { &[1, 4, 15] } else { &[1, 2, 4, 8, 15, 30] };
    let ns: &[usize] = if quick { &[3] } else { &[2, 3, 5] };

    println!("Fig. 4(a): step compression S vs (W, N), G = W — chat suite (MT-Bench analogue)\n");
    let mut table = Table::new(&["N", "W=G", "T_in", "S", "ms/step", "pool-hit%"]);
    let mut points: Vec<(usize, usize, f64)> = Vec::new(); // (gamma, b, S)
    for &n in ns {
        for &w in ws {
            let t_in = 2 * w * (n - 1);
            if t_in > 256 {
                continue; // generic executable cap
            }
            let mut cfg = LookaheadConfig::new(w, n, w);
            cfg.force_generic = true; // uniform executable across the sweep
            let mut engine = Lookahead::new(cfg);
            let run = run_suite_with(&rt, &mut engine, &prompts,
                                     SuiteOptions::new(max_tokens))?.run;
            table.row(vec![
                n.to_string(),
                w.to_string(),
                t_in.to_string(),
                format!("{:.3}", run.s()),
                format!("{:.1}", run.ms_per_step()),
                format!("{:.0}", 100.0 * run.pool_hits as f64
                        / (run.pool_hits + run.pool_misses).max(1) as f64),
            ]);
            points.push((n - 1, w, run.s()));
        }
    }
    table.print();

    // ---- Fig. 4(b): fit Eq. 7 and print the analytic curve ----------------
    let (alpha, f) = analytic::fit_alpha_f(&points);
    println!("\nFig. 4(b): Eq. 7 fit to the measurements: alpha = {alpha:.3}, \
              f = {f:.3}  (paper: alpha = 0.425, f = 3.106)\n");
    let mut t2 = Table::new(&["gamma=N-1", "b=W=G", "S_measured", "S_analytic"]);
    for &(g, b, s) in &points {
        t2.row(vec![
            g.to_string(),
            b.to_string(),
            format!("{s:.3}"),
            format!("{:.3}", analytic::compression(alpha, g, b, f)),
        ]);
    }
    t2.print();

    // linear-in-log(b) check: print increments per doubling at the largest N
    let n_big = *ns.last().unwrap();
    let series: Vec<(usize, f64)> = points
        .iter()
        .filter(|&&(g, _, _)| g == n_big - 1)
        .map(|&(_, b, s)| (b, s))
        .collect();
    println!("\nscaling-law check (N={n_big}): S per doubling of W=G:");
    for win in series.windows(2) {
        println!("  W {:>2} -> {:>2}: dS = {:+.3}", win[0].0, win[1].0,
                 win[1].1 - win[0].1);
    }

    save_result("fig4_scaling", Json::obj(vec![
        ("alpha", Json::num(alpha)),
        ("f", Json::num(f)),
        ("measured", Json::Arr(points.iter().map(|&(g, b, s)| {
            Json::obj(vec![("gamma", Json::num(g as f64)),
                           ("b", Json::num(b as f64)),
                           ("s", Json::num(s))])
        }).collect())),
        ("table", table.to_json()),
    ]));
    Ok(())
}
