//! Tab. 2 — sampling with LOOKAHEAD DECODING on summarization (paper:
//! CNN/Daily Mail + XSum, LLaMA-2-7B-Chat, temperature 0 and 1).
//!
//! Columns reproduced: ROUGE-1/2/L, speedup vs autoregressive, and the
//! compression ratio S. ROUGE references are the greedy autoregressive
//! outputs (the invariance claim: lookahead must not change quality).
//! Expected shape: LA rouge == AR rouge at temp 0 (byte-identical) and
//! statistically equal at temp 1; sampling S < greedy S.
//!
//!   cargo bench --bench tab2_sampling [-- --quick]

use lookahead::analytic::A100;
use lookahead::bench::driver::{run_suite_with, SuiteOptions};
use lookahead::bench::{bench_args, save_result, Table};
use lookahead::engine::autoregressive::AutoRegressive;
use lookahead::engine::lookahead::Lookahead;
use lookahead::metrics::rouge::rouge_suite;
use lookahead::runtime::load_model;
use lookahead::util::json::Json;
use lookahead::workload::Workloads;

fn main() -> anyhow::Result<()> {
    let args = bench_args();
    let quick = args.bool_or("quick", false);
    let (_, rt) = load_model("artifacts", "tiny")?;
    let workloads = Workloads::load("artifacts")?;
    let prompts = workloads.take("summarize", if quick { 3 } else { 10 })?;
    let max_tokens = if quick { 32 } else { 64 };
    let wng = (15usize, 5usize, 15usize);
    let t_in = (wng.0 + wng.2) * (wng.1 - 1);

    // ROUGE reference: greedy AR outputs (the paper scores against dataset
    // references; the invariance argument is the same — see DESIGN.md §2).
    let reference = run_suite_with(&rt, &mut AutoRegressive::new(), &prompts,
                                   SuiteOptions::new(max_tokens))?.texts;

    println!("Tab. 2: sampling with lookahead on the summarize suite \
              (XSum/CNN-DM analogue)\n");
    let mut table = Table::new(&["temp", "method", "Rouge-1", "Rouge-2", "Rouge-L",
                                 "cpu_x", "A100_proj_x", "S"]);
    let mut rows = Vec::new();
    for temp in [1.0f64, 0.0] {
        let mut ar_tps = 0.0;
        for method in ["AR", "LA"] {
            let opts = SuiteOptions::new(max_tokens).temperature(temp);
            let out = if method == "AR" {
                run_suite_with(&rt, &mut AutoRegressive::new(), &prompts, opts)?
            } else {
                let mut e = Lookahead::with_wng(wng.0, wng.1, wng.2);
                run_suite_with(&rt, &mut e, &prompts, opts)?
            };
            let (run, texts) = (out.run, out.texts);
            let pairs: Vec<(String, String)> = texts
                .iter()
                .cloned()
                .zip(reference.iter().cloned())
                .collect();
            let (r1, r2, rl) = rouge_suite(&pairs);
            if method == "AR" {
                ar_tps = run.tok_per_sec();
            }
            let cpu_x = run.tok_per_sec() / ar_tps;
            let proj = if method == "AR" { 1.0 } else {
                run.projected(&A100, 7e9, t_in)
            };
            table.row(vec![
                format!("{temp:.1}"),
                method.into(),
                format!("{r1:.2}"),
                format!("{r2:.2}"),
                format!("{rl:.2}"),
                format!("{cpu_x:.2}x"),
                format!("{proj:.2}x"),
                format!("{:.2}", run.s()),
            ]);
            rows.push(Json::obj(vec![
                ("temp", Json::num(temp)),
                ("method", Json::str(method)),
                ("rouge1", Json::num(r1)),
                ("rouge2", Json::num(r2)),
                ("rougeL", Json::num(rl)),
                ("s", Json::num(run.s())),
                ("a100_proj", Json::num(proj)),
            ]));
        }
    }
    table.print();
    println!("\npaper expectation: LA rouge == AR rouge per temperature; temp 0 \
              speedup/S > temp 1 (sampling lowers acceptance); at temp 0 LA text \
              is byte-identical to AR so Rouge-* = 100.");
    save_result("tab2_sampling", Json::Arr(rows));
    Ok(())
}
