//! Design-choice ablations beyond the paper's tables (DESIGN.md §4):
//!
//!   (a) n-gram pool capacity: per-key LRU depth + global cap vs S — how
//!       much history the pool actually needs;
//!   (b) prompt-as-reference seeding vs pool-only (isolated, per suite);
//!   (c) window-refill policy after multi-token acceptance (random refill
//!       vs repeat-last) — the paper leaves this unspecified (§3.1);
//!   (d) scheduler policy under mixed prompt lengths: FIFO vs SJF mean
//!       queue wait at the serving layer.
//!
//!   cargo bench --bench ablation_design [-- --quick]

use lookahead::bench::driver::{run_suite_with, SuiteOptions};
use lookahead::bench::{bench_args, save_result, Table};
use lookahead::engine::lookahead::{Lookahead, LookaheadConfig};
use lookahead::runtime::load_model;
use lookahead::server::{Policy, Request, ServerConfig, ServerHandle};
use lookahead::util::json::Json;
use lookahead::workload::Workloads;

fn main() -> anyhow::Result<()> {
    let args = bench_args();
    let quick = args.bool_or("quick", false);
    let (_, rt) = load_model("artifacts", "tiny")?;
    let workloads = Workloads::load("artifacts")?;
    let max_tokens = if quick { 32 } else { 64 };
    let nprompts = if quick { 2 } else { 4 };

    // ---- (a) pool capacity sweep -----------------------------------------
    println!("(a) n-gram pool capacity vs S (code suite, (15,5,15)):\n");
    let prompts = workloads.take("code", nprompts)?;
    let mut t = Table::new(&["per-key cap", "global cap", "S", "pool-hit%"]);
    let mut rows = Vec::new();
    for (pk, total) in [(1usize, 64usize), (4, 256), (8, 1024), (30, 16384)] {
        let mut cfg = LookaheadConfig::new(15, 5, 15);
        cfg.pool_per_key = pk;
        cfg.pool_total = total;
        let run = run_suite_with(&rt, &mut Lookahead::new(cfg), &prompts,
                                 SuiteOptions::new(max_tokens))?.run;
        t.row(vec![
            pk.to_string(),
            total.to_string(),
            format!("{:.2}", run.s()),
            format!("{:.0}", 100.0 * run.pool_hits as f64
                    / (run.pool_hits + run.pool_misses).max(1) as f64),
        ]);
        rows.push(Json::obj(vec![
            ("per_key", Json::num(pk as f64)),
            ("s", Json::num(run.s())),
        ]));
    }
    t.print();

    // ---- (b) prompt-as-reference per suite ---------------------------------
    println!("\n(b) prompt-as-reference contribution per suite ((15,5,15)):\n");
    let mut t = Table::new(&["suite", "S pool-only", "S +prompt-ref", "delta"]);
    for suite in ["chat", "code", "summarize"] {
        let prompts = workloads.take(suite, nprompts)?;
        let mut off = LookaheadConfig::new(15, 5, 15);
        off.prompt_as_ref = false;
        let s_off = run_suite_with(&rt, &mut Lookahead::new(off), &prompts,
                                   SuiteOptions::new(max_tokens))?.run.s();
        let s_on = run_suite_with(&rt, &mut Lookahead::with_wng(15, 5, 15), &prompts,
                                  SuiteOptions::new(max_tokens))?.run.s();
        t.row(vec![
            suite.into(),
            format!("{s_off:.2}"),
            format!("{s_on:.2}"),
            format!("{:+.2}", s_on - s_off),
        ]);
    }
    t.print();

    // ---- (d) scheduler policy under mixed lengths ---------------------------
    println!("\n(d) scheduler policy: mean queue wait, mixed prompt lengths:\n");
    let mut t = Table::new(&["policy", "mean queue ms", "p99 queue ms"]);
    for (name, policy) in [("fifo", Policy::Fifo), ("sjf", Policy::ShortestFirst)] {
        let h = ServerHandle::start(
            ServerConfig::builder()
                .policy(policy)
                .queue_depth(256)
                .share_ngrams(false) // isolate scheduler effects from cache warmth
                .build(),
        )?;
        // warm the worker first (engine + prefill compilation must not
        // land on a measured request — it would dwarf queue-wait deltas)
        let warm = h.submit(Request::new("def warm():\n").max_tokens(2))?;
        warm.wait()?;
        // alternate long prompts (class-code, long generations) with short
        // ones (math, short generations) — the head-of-line blocking case.
        // SJF keys on prompt length, so the prompts themselves must differ.
        let long_ps = workloads.take("class-code", 4)?;
        let short_ps: Vec<String> = workloads.take("math", 4)?
            .into_iter().map(|p| p[p.len().saturating_sub(24)..].to_string())
            .collect();
        let mut rxs = Vec::new();
        for i in 0..(if quick { 4 } else { 8 }) {
            let long = i % 2 == 0;
            rxs.push(h.submit(
                Request::new(if long { long_ps[i / 2 % 4].clone() }
                             else { short_ps[i / 2 % 4].clone() })
                    .max_tokens(if long { max_tokens } else { 8 }),
            )?);
        }
        let mut q = lookahead::metrics::Histogram::new();
        for rx in rxs {
            let r = rx.wait()?;
            anyhow::ensure!(r.error.is_none(), "{:?}", r.error);
            q.record(r.queue_ms);
        }
        t.row(vec![name.into(), format!("{:.0}", q.mean()),
                   format!("{:.0}", q.p99())]);
        h.shutdown();
    }
    t.print();
    println!("\n(SJF should cut mean wait when short and long requests mix.)");

    save_result("ablation_design", Json::Arr(rows));
    Ok(())
}
