//! Cross-request shared n-gram cache bench — the serving scenario from
//! `examples/chat_serving.rs`: a small set of templated prompts re-served
//! over several rounds, as production traffic does (shared system prompts,
//! boilerplate completions).
//!
//! Cold = every request decodes against a fresh private pool (the paper's
//! per-request setting). Warm = all requests share one `SharedNgramCache`,
//! so round r+1 starts with the n-grams rounds 1..r harvested. Greedy
//! verification keeps outputs byte-identical either way — the cache can
//! only raise the mean accepted-tokens-per-step S, never change text.
//!
//!   cargo bench --bench shared_cache [-- --quick]

use std::sync::Arc;

use lookahead::bench::driver::{run_suite_with, SuiteOptions, SuiteRun};
use lookahead::bench::{bench_args, save_result, Table};
use lookahead::engine::lookahead::Lookahead;
use lookahead::engine::prompt_lookup::PromptLookup;
use lookahead::engine::Decoder;
use lookahead::ngram::{SharedCacheStats, SharedNgramCache};
use lookahead::runtime::ModelRuntime;
use lookahead::util::json::Json;
use lookahead::workload::Workloads;

/// Run the same templated stream cold (private per-request pools) and warm
/// (one shared cache), asserting byte-identical outputs.
fn cold_vs_warm(rt: &ModelRuntime, engine: &mut dyn Decoder, stream: &[String],
                max_tokens: usize)
                -> anyhow::Result<(SuiteRun, SuiteRun, SharedCacheStats)> {
    let cold = run_suite_with(rt, engine, stream, SuiteOptions::new(max_tokens))?;
    let cache = Arc::new(SharedNgramCache::with_defaults(
        engine.pool_spec().expect("engine keeps no pool"),
    ));
    let warm = run_suite_with(rt, engine, stream,
                              SuiteOptions::new(max_tokens).cache(&cache))?;
    assert_eq!(cold.texts, warm.texts,
               "shared cache changed greedy output bytes — losslessness broken");
    Ok((cold.run, warm.run, cache.stats()))
}

fn main() -> anyhow::Result<()> {
    let args = bench_args();
    let quick = args.bool_or("quick", false);
    if lookahead::bench::skip_without_artifacts("shared_cache bench") {
        return Ok(());
    }
    let (_, rt) = lookahead::runtime::load_model("artifacts", "tiny")?;
    let workloads = Workloads::load("artifacts")?;

    // Templated serving traffic: few distinct prompts, many rounds.
    let base = workloads.take("chat", if quick { 2 } else { 3 })?;
    let rounds = if quick { 2 } else { 4 };
    let mut stream: Vec<String> = Vec::with_capacity(base.len() * rounds);
    for _ in 0..rounds {
        stream.extend(base.iter().cloned());
    }
    let max_tokens = if quick { 32 } else { 64 };

    println!("shared n-gram cache: {} requests ({} templates x {} rounds), \
              {} max tokens\n",
             stream.len(), base.len(), rounds, max_tokens);

    let mut table = Table::new(&["engine", "pool", "S", "hit%", "warm-starts",
                                 "steps"]);
    let mut rows = Vec::new();
    let mut headline: Option<(f64, f64)> = None;

    let mut la = Lookahead::with_wng(15, 5, 15);
    let mut pl = PromptLookup::new(8, 1);
    let engines: [(&str, &mut dyn Decoder); 2] =
        [("lookahead[w15n5g15]", &mut la), ("prompt_lookup[k8]", &mut pl)];
    for (name, engine) in engines {
        let (cold, warm, cache) = cold_vs_warm(&rt, engine, &stream, max_tokens)?;
        for (tag, run) in [("cold", &cold), ("warm", &warm)] {
            table.row(vec![
                name.into(),
                tag.into(),
                format!("{:.3}", run.s()),
                format!("{:.0}", 100.0 * run.pool_hit_rate()),
                format!("{}/{}", run.warm_starts, run.prompts),
                run.steps.to_string(),
            ]);
        }
        if headline.is_none() {
            headline = Some((cold.s(), warm.s()));
        }
        rows.push(Json::obj(vec![
            ("engine", Json::str(name)),
            ("cold_s", Json::num(cold.s())),
            ("warm_s", Json::num(warm.s())),
            ("cold_hit_rate", Json::num(cold.pool_hit_rate())),
            ("warm_hit_rate", Json::num(warm.pool_hit_rate())),
            ("warm_starts", Json::num(warm.warm_starts as f64)),
            ("cache_entries", Json::num(cache.entries as f64)),
            ("cache_evictions", Json::num(cache.evictions as f64)),
        ]));
    }

    table.print();
    if let Some((cold_s, warm_s)) = headline {
        println!("\nheadline: warm shared cache S = {warm_s:.3} vs cold S = \
                  {cold_s:.3} ({:+.1}% accepted tokens/step on repeated \
                  templates)",
                 100.0 * (warm_s / cold_s.max(1e-9) - 1.0));
    }
    println!("outputs byte-identical cold vs warm (greedy losslessness held).");
    save_result("shared_cache", Json::Arr(rows));
    Ok(())
}
