//! Fig. 6/7 — Lookahead Parallelism strong scaling on 1-8 devices, plus the
//! FlashAttention-analogue ablation (specialized/hardcoded-mask executable
//! vs the generic mask-as-input one) and the TP/PP comparison (paper:
//! DeepSpeed TP and Accelerate PP slow single-batch decoding to 0.75-0.82x).
//!
//! Per DESIGN.md §2, LP is a measurement-driven simulation on this 1-core
//! box: real shard-sized steps are executed to get per-device compute time;
//! TP/PP use the analytic communication model at paper (7B, A100) scale.
//!
//!   cargo bench --bench fig6_7_lp [-- --quick]

use lookahead::analytic::{parallel_step_latency, step_latency, Parallelism, A100};
use lookahead::bench::driver::{run_suite_with, SuiteOptions};
use lookahead::bench::{bench_args, save_result, Table};
use lookahead::engine::lookahead::{Lookahead, LookaheadConfig};
use lookahead::layout::Wng;
use lookahead::runtime::load_model;
use lookahead::tokenizer::ByteTokenizer;
use lookahead::util::json::Json;
use lookahead::workload::Workloads;

fn main() -> anyhow::Result<()> {
    let args = bench_args();
    let quick = args.bool_or("quick", false);
    let (_, rt) = load_model("artifacts", "tiny")?;
    let workloads = Workloads::load("artifacts")?;
    let tok = ByteTokenizer::new();
    let wng = Wng::new(15, 5, 15);

    // -- measured S for the config (LP does not change S, paper App. E) ----
    let prompts = workloads.take("class-code", if quick { 2 } else { 3 })?;
    let mut engine = Lookahead::with_wng(wng.w, wng.n, wng.g);
    let full = run_suite_with(&rt, &mut engine, &prompts,
                              SuiteOptions::new(if quick { 32 } else { 64 }))?.run;
    let s = full.s();
    println!("measured S = {s:.2} for {:?} on class-code (ClassEval analogue)\n", wng);

    // -- LP device sweep, mode (i): fixed config sharded across K devices --
    // Per-device t_in shrinks, so the per-step latency falls toward the
    // kernel-launch floor (measured with real shard-sized steps).
    let (_, cache) = rt.prefill(&tok.encode_with_bos("def warm():\n    return 1"))?;
    println!("Fig. 6/7 LP mode (i): fixed (15,5,15) sharded — measured shard steps");
    let mut table = Table::new(&["devices", "max shard T_in", "shard ms (measured)",
                                 "comm ms", "step ms", "tok/s", "scaling vs 1dev"]);
    let mut base_tps = 0.0;
    let mut rows = Vec::new();
    for devices in [1usize, 2, 4, 8] {
        let rep = lookahead::lp::simulate(&rt, &cache, wng, devices, s,
                                          if quick { 2 } else { 5 })?;
        if base_tps == 0.0 {
            base_tps = rep.tokens_per_sec;
        }
        let max_t = rep.shards.iter().map(|sh| sh.t_in).max().unwrap_or(0);
        let max_ms = rep.shard_ms.iter().cloned().fold(0.0, f64::max);
        table.row(vec![
            devices.to_string(),
            max_t.to_string(),
            format!("{max_ms:.2}"),
            format!("{:.4}", rep.comm_ms),
            format!("{:.2}", rep.step_ms),
            format!("{:.1}", rep.tokens_per_sec),
            format!("{:.2}x", rep.tokens_per_sec / base_tps),
        ]);
        rows.push(Json::obj(vec![
            ("devices", Json::num(devices as f64)),
            ("step_ms", Json::num(rep.step_ms)),
            ("tokens_per_sec", Json::num(rep.tokens_per_sec)),
        ]));
    }
    table.print();

    // -- LP mode (ii): scale (W, G) with the device count (paper §3.4) -----
    // Each device keeps the single-GPU per-step budget (t_in = 120); the
    // effective window grows K-fold, so S grows along the Eq. 7 curve fitted
    // to *measured* points, at ~constant per-step latency. This is how the
    // paper reaches 4x on ClassEval with 8 GPUs.
    println!("\nFig. 6/7 LP mode (ii): scale W=G with devices (per-device budget \
              constant)");
    let fit_ws: &[usize] = if quick { &[4, 15] } else { &[2, 4, 8, 15] };
    let mut pts = Vec::new();
    for &w in fit_ws {
        let mut cfg = LookaheadConfig::new(w, wng.n, w);
        cfg.force_generic = true;
        let run = run_suite_with(&rt, &mut Lookahead::new(cfg), &prompts,
                                 SuiteOptions::new(if quick { 32 } else { 48 }))?.run;
        pts.push((wng.n - 1, w, run.s()));
    }
    let (alpha, f) = lookahead::analytic::fit_alpha_f(&pts);
    let rep1 = lookahead::lp::simulate(&rt, &cache, wng, 1, 1.0,
                                       if quick { 2 } else { 5 })?;
    let mut t1b = Table::new(&["devices", "effective W=G", "S (Eq.7, fitted)",
                               "step ms", "tok/s", "scaling vs 1dev"]);
    let mut base2 = 0.0;
    for devices in [1usize, 2, 4, 8] {
        let eff_b = wng.w * devices;
        let s_eff = if devices == 1 {
            s // measured
        } else {
            // anchor the fitted curve at the measured single-device S
            s * lookahead::analytic::compression(alpha, wng.n - 1, eff_b, f)
                / lookahead::analytic::compression(alpha, wng.n - 1, wng.w, f)
        };
        let step_ms = rep1.step_ms + 0.008 * (devices > 1) as u8 as f64;
        let tps = s_eff * 1e3 / step_ms;
        if base2 == 0.0 {
            base2 = tps;
        }
        t1b.row(vec![
            devices.to_string(),
            eff_b.to_string(),
            format!("{s_eff:.2}"),
            format!("{step_ms:.2}"),
            format!("{tps:.1}"),
            format!("{:.2}x", tps / base2),
        ]);
    }
    t1b.print();
    println!("(alpha = {alpha:.3}, f = {f:.3} fitted to measured S at W = {fit_ws:?})");

    // -- TP/PP comparison at paper scale (analytic, Fig. 6/7 baselines) ----
    println!("\nTP/PP baselines at paper scale (7B fp16, A100, t_in = 1 AR decode):");
    let mut t2 = Table::new(&["scheme", "devices", "step ms", "vs 1-GPU AR"]);
    let base = step_latency(&A100, 7e9, 1) * 1e3;
    t2.row(vec!["1 GPU AR".into(), "1".into(), format!("{base:.2}"), "1.00x".into()]);
    for devices in [2usize, 4, 8] {
        for (name, p) in [("TP (DeepSpeed)", Parallelism::TP),
                          ("PP (Accelerate)", Parallelism::PP)] {
            let ms = parallel_step_latency(p, &A100, devices, 7e9, 32, 4096, 1) * 1e3;
            t2.row(vec![
                name.into(),
                devices.to_string(),
                format!("{ms:.2}"),
                format!("{:.2}x", base / ms),
            ]);
        }
        let lp_ms =
            parallel_step_latency(Parallelism::LP, &A100, devices, 7e9, 32, 4096,
                                  wng.t_in()) * 1e3;
        t2.row(vec![
            "LP (ours)".into(),
            devices.to_string(),
            format!("{lp_ms:.2}"),
            format!("{:.2}x", s * base / lp_ms),
        ]);
    }
    t2.print();
    println!("\npaper expectation: TP/PP 0.75-0.82x at batch 1; LP scales up \
              (up to 4x on ClassEval with 8 GPUs).");

    // -- FlashAttention-analogue ablation -----------------------------------
    println!("\nFlashAttention-analogue ablation (hardcoded-mask specialized vs \
              generic mask-as-input executable):");
    let mut t3 = Table::new(&["executable", "S", "ms/step", "note"]);
    for (label, force_generic, note) in [
        ("specialized (hardcoded mask)", false, "paper's FA-integrated path"),
        ("generic (mask as input)", true, "paper's 'naive PyTorch' analogue"),
    ] {
        let mut cfg = LookaheadConfig::new(wng.w, wng.n, wng.g);
        cfg.force_generic = force_generic;
        let mut e = Lookahead::new(cfg);
        let run = run_suite_with(&rt, &mut e, &prompts,
                                 SuiteOptions::new(if quick { 32 } else { 64 }))?.run;
        t3.row(vec![label.into(), format!("{:.2}", run.s()),
                    format!("{:.1}", run.ms_per_step()), note.into()]);
    }
    t3.print();
    println!("(paper: FlashAttention integration gives ~20% end-to-end; here the \
              specialized path saves the T_pad overhead + mask upload)");

    save_result("fig6_7_lp", Json::Arr(rows));
    Ok(())
}
