//! Tab. 4 — "good configurations" of (W, N) per model size with G = W
//! (paper: A100, single-batch serving — (15,5) for 7B, (10,5) for 13B,
//! (7,5) for 34B).
//!
//! For each model we sweep a (W, N) grid, score by A100-projected
//! throughput (S over the memory-bound per-step cost of T_in), and report
//! the best configuration.
//!
//! Expected shape: optimum W shrinks as the model grows (bigger models hit
//! the FLOPs cap earlier — paper §5.5).
//!
//!   cargo bench --bench tab4_config [-- --quick]

use lookahead::analytic::A100;
use lookahead::bench::driver::{run_suite_with, SuiteOptions};
use lookahead::bench::{bench_args, save_result, Table};
use lookahead::engine::lookahead::{Lookahead, LookaheadConfig};
use lookahead::runtime::{cpu_client, Manifest, ModelRuntime};
use lookahead::util::json::Json;
use lookahead::workload::Workloads;

fn main() -> anyhow::Result<()> {
    let args = bench_args();
    let quick = args.bool_or("quick", false);
    let manifest = Manifest::load("artifacts")?;
    let client = cpu_client()?;
    let workloads = Workloads::load("artifacts")?;
    let prompts = workloads.take("chat", if quick { 2 } else { 3 })?;
    let max_tokens = if quick { 32 } else { 48 };

    let ws: &[usize] = if quick { &[7, 15] } else { &[4, 7, 10, 15, 22, 30] };
    let ns: &[usize] = if quick { &[5] } else { &[3, 5] };
    // model-size axis: tiny plays the 7B row, small the 13B row.
    let models: Vec<(&str, f64)> = if quick || !manifest.models.contains_key("small") {
        vec![("tiny", 7e9)]
    } else {
        vec![("tiny", 7e9), ("small", 13e9)]
    };

    println!("Tab. 4: best (W, N) per model size, G = W, scored by A100-projected \
              throughput\n");
    let mut table = Table::new(&["model(paper)", "W", "N", "T_in", "S",
                                 "A100_proj_x", "best?"]);
    let mut best_rows = Vec::new();
    for (model, paper_params) in models {
        let rt = ModelRuntime::load(&client, &manifest, model)?;
        let mut best: Option<(usize, usize, f64, f64)> = None; // (w, n, proj, s)
        let mut rows = Vec::new();
        for &n in ns {
            for &w in ws {
                let t_in = 2 * w * (n - 1);
                if t_in > 256 {
                    continue;
                }
                let mut cfg = LookaheadConfig::new(w, n, w);
                cfg.force_generic = true;
                let run = run_suite_with(&rt, &mut Lookahead::new(cfg), &prompts,
                                         SuiteOptions::new(max_tokens))?.run;
                let proj = run.projected(&A100, paper_params, t_in);
                rows.push((w, n, t_in, run.s(), proj));
                if best.map_or(true, |(_, _, bp, _)| proj > bp) {
                    best = Some((w, n, proj, run.s()));
                }
            }
        }
        let (bw, bn, _, _) = best.unwrap();
        for (w, n, t_in, s, proj) in rows {
            let label = if model == "tiny" { "tiny(7B)" } else { "small(13B)" };
            table.row(vec![
                label.into(),
                w.to_string(),
                n.to_string(),
                t_in.to_string(),
                format!("{s:.2}"),
                format!("{proj:.2}x"),
                if (w, n) == (bw, bn) { "<-- best".into() } else { "".into() },
            ]);
        }
        best_rows.push(Json::obj(vec![
            ("model", Json::str(model)),
            ("best_w", Json::num(bw as f64)),
            ("best_n", Json::num(bn as f64)),
        ]));
    }
    table.print();
    println!("\npaper: (W,N) = (15,5) for 7B and (10,5) for 13B; the best W \
              should not grow with model size.");
    save_result("tab4_config", Json::Arr(best_rows));
    Ok(())
}
