//! Tab. 3 — ablation of the lookahead and verification branches on the chat
//! suite (paper: LLaMA-2-7B-Chat on MT-Bench, A100, FlashAttention on).
//!
//! Rows ①-⑨ exactly as the paper: autoregressive, prompt-lookup, minimal
//! lookahead branch (W=1) with various (N,G), lopsided branches, balanced
//! branches, each with/without prompt-as-reference.
//!
//! Expected shape: balanced (⑧⑨) > lopsided (⑦) > W=1 configs (③-⑥) >
//! prompt-lookup (②) > AR (①); prompt-as-ref helps everywhere.
//!
//!   cargo bench --bench tab3_ablation [-- --quick]

use lookahead::analytic::A100;
use lookahead::bench::driver::{run_suite_with, SuiteOptions};
use lookahead::bench::{bench_args, save_result, Table};
use lookahead::engine::autoregressive::AutoRegressive;
use lookahead::engine::lookahead::{Lookahead, LookaheadConfig};
use lookahead::engine::prompt_lookup::PromptLookup;
use lookahead::runtime::load_model;
use lookahead::util::json::Json;
use lookahead::workload::Workloads;

fn main() -> anyhow::Result<()> {
    let args = bench_args();
    let quick = args.bool_or("quick", false);
    let (_, rt) = load_model("artifacts", "tiny")?;
    let workloads = Workloads::load("artifacts")?;
    let prompts = workloads.take("chat", if quick { 2 } else { 4 })?;
    let max_tokens = if quick { 32 } else { 64 };

    // (tag, (N, W, G) in the paper's order, prompt_as_ref) — None = baseline
    let configs: Vec<(&str, Option<(usize, usize, usize)>, bool, &str)> = vec![
        ("1", None, false, "autoregressive"),
        ("2", None, true, "prompt lookup"),
        ("3", Some((10, 1, 3)), true, "(N,W,G)=(10,1,3)"),
        ("4", Some((5, 1, 10)), true, "(5,1,10)"),
        ("5", Some((5, 1, 30)), false, "(5,1,30) no-pref"),
        ("6", Some((5, 1, 30)), true, "(5,1,30)"),
        ("7", Some((5, 30, 1)), false, "(5,30,1) no-pref"),
        ("8", Some((5, 15, 15)), false, "(5,15,15) no-pref"),
        ("9", Some((5, 15, 15)), true, "(5,15,15)"),
    ];

    println!("Tab. 3: branch ablation on the chat suite (MT-Bench analogue)\n");
    let mut table = Table::new(&["tag", "setting", "prompt-as-ref", "S",
                                 "cpu tok/s", "A100_proj_x"]);
    let mut rows = Vec::new();
    let mut ar_ref = 0.0;
    for (tag, cfg, pref, label) in configs {
        let opts = SuiteOptions::new(max_tokens);
        let (run, t_in) = match cfg {
            None if tag == "1" => {
                (run_suite_with(&rt, &mut AutoRegressive::new(), &prompts, opts)?
                     .run, 1)
            }
            None => {
                (run_suite_with(&rt, &mut PromptLookup::new(8, 1), &prompts, opts)?
                     .run, 8)
            }
            Some((n, w, g)) => {
                let mut c = LookaheadConfig::new(w, n, g);
                c.prompt_as_ref = pref;
                c.force_generic = true; // uniform executable across rows
                let t = (w + g) * (n - 1);
                (run_suite_with(&rt, &mut Lookahead::new(c), &prompts, opts)?.run, t)
            }
        };
        if tag == "1" {
            ar_ref = run.tok_per_sec();
        }
        let proj = if tag == "1" { 1.0 } else { run.projected(&A100, 7e9, t_in) };
        table.row(vec![
            tag.into(),
            label.into(),
            if pref { "yes".into() } else { "no".into() },
            format!("{:.2}", run.s()),
            format!("{:.1}", run.tok_per_sec()),
            format!("{proj:.2}x"),
        ]);
        rows.push(Json::obj(vec![
            ("tag", Json::str(tag)),
            ("setting", Json::str(label)),
            ("s", Json::num(run.s())),
            ("a100_proj", Json::num(proj)),
        ]));
        let _ = ar_ref;
    }
    table.print();
    println!("\npaper expectation: ⑨ (balanced + pref) best; ⑦ (G=1) below \
              balanced; W=1 rows give decent-but-lower S; ② beats ③ at equal \
              budget.");
    save_result("tab3_ablation", Json::Arr(rows));
    Ok(())
}
