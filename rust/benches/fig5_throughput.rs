//! Fig. 5 — end-to-end throughput of LOOKAHEAD DECODING vs autoregressive
//! greedy across datasets and model sizes (paper: LLaMA-2/CodeLlama
//! 7B/13B/34B on MT-Bench, HumanEval, GSM8K, MBPP — setting S1).
//!
//! Substitutions (DESIGN.md §2): synthetic suites stand in for the datasets;
//! {tiny, small} stand in for the size axis; the A100 projection column
//! translates measured S to the paper's memory-bound regime (this CPU is
//! compute-bound, so raw CPU tok/s understates lookahead).
//!
//! Expected shape: S(code/class-code) > S(math/summarize) > S(chat);
//! the smaller model compresses more than the bigger one.
//!
//!   cargo bench --bench fig5_throughput [-- --quick]

use lookahead::analytic::A100;
use lookahead::bench::driver::{run_suite_with, SuiteOptions};
use lookahead::bench::{bench_args, save_result, Table};
use lookahead::engine::autoregressive::AutoRegressive;
use lookahead::engine::lookahead::Lookahead;
use lookahead::runtime::{cpu_client, Manifest, ModelRuntime};
use lookahead::util::json::Json;
use lookahead::workload::{paper_dataset, Workloads, SUITE_NAMES};

fn main() -> anyhow::Result<()> {
    let args = bench_args();
    let quick = args.bool_or("quick", false);
    let manifest = Manifest::load("artifacts")?;
    let client = cpu_client()?;
    let workloads = Workloads::load("artifacts")?;
    let n_prompts = if quick { 2 } else { 4 };
    let max_tokens = if quick { 32 } else { 64 };

    // (model, lookahead config from Tab. 4; the "7B" row for tiny, "13B" for small)
    let models: Vec<(&str, (usize, usize, usize))> = if quick {
        vec![("tiny", (15, 5, 15))]
    } else {
        vec![("tiny", (15, 5, 15)), ("small", (10, 5, 10))]
    };

    println!("Fig. 5: lookahead vs autoregressive across suites and model sizes\n");
    let mut table = Table::new(&["model", "suite(=paper)", "S", "AR tok/s",
                                 "LA tok/s", "cpu_x", "A100_proj_x"]);
    let mut rows = Vec::new();
    for (model, wng) in &models {
        let rt = ModelRuntime::load(&client, &manifest, model)?;
        let t_in = (wng.0 + wng.2) * (wng.1 - 1);
        // paper-scale params for the projection: tiny ~ 7B, small ~ 13B
        let paper_params = if *model == "tiny" { 7e9 } else { 13e9 };
        for suite in SUITE_NAMES {
            let prompts = workloads.take(suite, n_prompts)?;
            let ar = run_suite_with(&rt, &mut AutoRegressive::new(), &prompts,
                                    SuiteOptions::new(max_tokens))?.run;
            let mut la_engine = Lookahead::with_wng(wng.0, wng.1, wng.2);
            let la = run_suite_with(&rt, &mut la_engine, &prompts,
                                    SuiteOptions::new(max_tokens))?.run;
            let proj = la.projected(&A100, paper_params, t_in);
            table.row(vec![
                model.to_string(),
                format!("{suite}({})", paper_dataset(suite)),
                format!("{:.2}", la.s()),
                format!("{:.1}", ar.tok_per_sec()),
                format!("{:.1}", la.tok_per_sec()),
                format!("{:.2}", la.tok_per_sec() / ar.tok_per_sec()),
                format!("{:.2}", proj),
            ]);
            rows.push(Json::obj(vec![
                ("model", Json::str(*model)),
                ("suite", Json::str(suite)),
                ("s", Json::num(la.s())),
                ("ar_tps", Json::num(ar.tok_per_sec())),
                ("la_tps", Json::num(la.tok_per_sec())),
                ("a100_projected_speedup", Json::num(proj)),
            ]));
        }
    }
    table.print();
    println!("\npaper expectation: 1.5x-2.3x on A100; code suites highest; \
              smaller models compress more.");
    save_result("fig5_throughput", Json::Arr(rows));
    Ok(())
}
