"""L2: LLaMA-style byte-level transformer with a static KV cache.

Defines the three step functions that are AOT-lowered to HLO text and executed
from the Rust coordinator (Python is never on the request path):

  - ``prefill``   : prompt -> (kv_cache, logits)
  - ``decode``    : (kv_cache, cache_len, step tokens) -> (logits, new_kv)
                    with either a *specialized* hardcoded lookahead mask
                    (jnp or Pallas attention) or a *generic* mask-as-input
                    variant used for (W, N, G) sweeps;
  - ``commit``    : scatter accepted-token K/V rows into the cache.

Weights are a flat **list** (positional, never a dict) so the HLO parameter
order is stable; `weight_names()` is recorded in the manifest and checked by
the Rust loader.

Cache layout: ``[L, 2, S, Hk*D]`` (2 = key/value). Row ``S-1`` is the junk row
— commit scatters unused slots there and visibility masks (`< cache_len`)
guarantee it is never attended.
"""

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from compile import masks
from compile.config import ModelConfig
from compile.kernels.lookahead_attn import lookahead_attention
from compile.kernels.ref import attention_ref


# ---------------------------------------------------------------------------
# Weights
# ---------------------------------------------------------------------------

def weight_names(cfg: ModelConfig) -> List[str]:
    names = ["embed"]
    for l in range(cfg.n_layers):
        names += [
            f"l{l}.attn_norm", f"l{l}.wq", f"l{l}.wk", f"l{l}.wv", f"l{l}.wo",
            f"l{l}.mlp_norm", f"l{l}.wg", f"l{l}.wu", f"l{l}.wd",
        ]
    names.append("final_norm")
    return names


def weight_shapes(cfg: ModelConfig) -> List[tuple]:
    d, hd = cfg.d_model, cfg.head_dim
    kvd = cfg.n_kv_heads * hd
    shapes = [(cfg.vocab, d)]
    for _ in range(cfg.n_layers):
        shapes += [
            (d,), (d, cfg.n_heads * hd), (d, kvd), (d, kvd),
            (cfg.n_heads * hd, d),
            (d,), (d, cfg.d_ff), (d, cfg.d_ff), (cfg.d_ff, d),
        ]
    shapes.append((d,))
    return shapes


def init_weights(cfg: ModelConfig, seed: int = 0) -> List[np.ndarray]:
    """He-style init, deterministic. Returned in canonical order."""
    rng = np.random.RandomState(seed)
    out = []
    for name, shape in zip(weight_names(cfg), weight_shapes(cfg)):
        if name.endswith("norm"):
            out.append(np.ones(shape, dtype=np.float32))
        elif name == "embed":
            out.append((rng.randn(*shape) * 0.02).astype(np.float32))
        else:
            fan_in = shape[0]
            out.append((rng.randn(*shape) / np.sqrt(fan_in)).astype(np.float32))
    return out


def cache_rows(cfg: ModelConfig) -> int:
    # total cache rows; last row is the junk row. Multiple of 128 for the
    # pallas Bk tiling.
    assert cfg.max_seq % 128 == 0
    return cfg.max_seq


def zero_cache(cfg: ModelConfig) -> np.ndarray:
    kvd = cfg.n_kv_heads * cfg.head_dim
    return np.zeros((cfg.n_layers, 2, cache_rows(cfg), kvd), dtype=np.float32)


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, gain: jnp.ndarray, eps: float) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * gain).astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: [T, H, D], positions: [T] int32."""
    t, h, d = x.shape
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # [T, half]
    cos = jnp.cos(angles)[:, None, :]  # [T, 1, half]
    sin = jnp.sin(angles)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


def _layer(cfg: ModelConfig, lw: Sequence[jnp.ndarray], x, positions,
           k_cache_l, v_cache_l, cache_len, intra, attn_impl, wng):
    """One transformer layer. Returns (x, k_new, v_new) with kv in [T,Hk,D]."""
    attn_norm, wq, wk, wv, wo, mlp_norm, wg, wu, wd = lw
    t = x.shape[0]
    hd = cfg.head_dim

    h = rmsnorm(x, attn_norm, cfg.norm_eps)
    q = (h @ wq).reshape(t, cfg.n_heads, hd)
    k = (h @ wk).reshape(t, cfg.n_kv_heads, hd)
    v = (h @ wv).reshape(t, cfg.n_kv_heads, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if attn_impl == "pallas":
        w, n, g = wng
        o = lookahead_attention(q, k, v, k_cache_l, v_cache_l, cache_len,
                                w, n, g)
    else:
        o = attention_ref(q, k, v, k_cache_l, v_cache_l, cache_len, intra)
    x = x + o.reshape(t, cfg.n_heads * hd) @ wo

    h = rmsnorm(x, mlp_norm, cfg.norm_eps)
    x = x + (jax.nn.silu(h @ wg) * (h @ wu)) @ wd
    return x, k, v


def _split_weights(cfg: ModelConfig, weights: Sequence[jnp.ndarray]):
    embed = weights[0]
    layers = []
    for l in range(cfg.n_layers):
        layers.append(weights[1 + 9 * l: 1 + 9 * (l + 1)])
    final_norm = weights[-1]
    return embed, layers, final_norm


def forward_step(cfg: ModelConfig, weights, cache, cache_len, tokens,
                 positions, intra, attn_impl="jnp", wng=None):
    """Shared forward over T step tokens against the committed cache.

    Returns (logits [T, vocab], new_kv [L, 2, T, Hk*D]).
    """
    embed, layers, final_norm = _split_weights(cfg, weights)
    t = tokens.shape[0]
    kvd = cfg.n_kv_heads * cfg.head_dim

    x = embed[tokens]  # [T, d]
    new_kv = []
    for l, lw in enumerate(layers):
        k_cache_l = cache[l, 0].reshape(-1, cfg.n_kv_heads, cfg.head_dim)
        v_cache_l = cache[l, 1].reshape(-1, cfg.n_kv_heads, cfg.head_dim)
        x, k, v = _layer(cfg, lw, x, positions, k_cache_l, v_cache_l,
                         cache_len, intra, attn_impl, wng)
        new_kv.append(jnp.stack([k.reshape(t, kvd), v.reshape(t, kvd)]))
    x = rmsnorm(x, final_norm, cfg.norm_eps)
    logits = x @ embed.T  # tied embeddings
    return logits.astype(jnp.float32), jnp.stack(new_kv)


# ---------------------------------------------------------------------------
# AOT entry points (the functions that become HLO artifacts)
# ---------------------------------------------------------------------------

def make_prefill(cfg: ModelConfig, prompt_len: int):
    """prefill(weights.., tokens i32[P], n_valid i32) -> (cache, logits[P,V]).

    Fills cache rows 0..P-1 (the Rust side sets cache_len = n_valid - 1; rows
    beyond are never attended). Padded positions produce garbage KV that is
    likewise never visible.
    """
    s = cache_rows(cfg)
    intra = jnp.asarray(np.tril(np.ones((prompt_len, prompt_len), dtype=bool)))

    def prefill(*args):
        weights = args[:-2]
        tokens, n_valid = args[-2], args[-1]
        positions = jnp.arange(prompt_len, dtype=jnp.int32)
        cache = jnp.zeros((cfg.n_layers, 2, s, cfg.n_kv_heads * cfg.head_dim),
                          dtype=jnp.float32)
        zero_len = jnp.asarray(0, dtype=jnp.int32)
        logits, new_kv = forward_step(
            cfg, weights, cache, zero_len, tokens, positions, intra)
        # new_kv: [L,2,P,KVD] -> rows 0..P-1 of the cache
        cache = jax.lax.dynamic_update_slice(cache, new_kv, (0, 0, 0, 0))
        del n_valid  # kept in the signature for the runtime contract
        return logits, cache

    return prefill


def make_decode_specialized(cfg: ModelConfig, w: int, n: int, g: int,
                            attn_impl: str = "jnp"):
    """decode(weights.., cache, cache_len i32, tokens i32[T]) ->
    (logits [T,V], new_kv [L,2,T,KVD]) with the (W,N,G) pattern baked in."""
    intra = jnp.asarray(masks.intra_mask_vectorized(w, n, g))
    relpos = jnp.asarray(masks.relative_positions(w, n, g))

    def decode(*args):
        weights = args[:-3]
        cache, cache_len, tokens = args[-3], args[-2], args[-1]
        positions = (cache_len + relpos).astype(jnp.int32)
        return forward_step(cfg, weights, cache, cache_len, tokens, positions,
                            intra, attn_impl=attn_impl, wng=(w, n, g))

    return decode


def make_decode_linear(cfg: ModelConfig, k: int):
    """Plain causal chain over k new tokens (AR step / draft verify)."""
    intra = jnp.asarray(masks.linear_mask(k))

    def decode(*args):
        weights = args[:-3]
        cache, cache_len, tokens = args[-3], args[-2], args[-1]
        positions = (cache_len + jnp.arange(k, dtype=jnp.int32)).astype(jnp.int32)
        return forward_step(cfg, weights, cache, cache_len, tokens, positions,
                            intra)

    return decode


def make_decode_generic(cfg: ModelConfig, t_pad: int):
    """Mask-as-input decode used for (W,N,G) sweeps without re-lowering.

    decode(weights.., cache, cache_len, tokens i32[T], relpos i32[T],
           mask u8[T,T]) -> (logits, new_kv)
    """

    def decode(*args):
        weights = args[:-5]
        cache, cache_len, tokens, relpos, mask_u8 = args[-5:]
        intra = mask_u8.astype(jnp.bool_)
        positions = (cache_len + relpos).astype(jnp.int32)
        return forward_step(cfg, weights, cache, cache_len, tokens, positions,
                            intra)

    return decode


def make_commit(cfg: ModelConfig, t: int, slots: int = 8):
    """commit(cache, new_kv[L,2,T,KVD], src_idx i32[slots], dest_start i32,
    count i32) -> cache.

    Scatters `count` rows of new_kv (selected by src_idx) to cache rows
    dest_start..dest_start+count-1; unused slots land on the junk row S-1.
    """
    s = cache_rows(cfg)

    def commit(cache, new_kv, src_idx, dest_start, count):
        i = jnp.arange(slots, dtype=jnp.int32)
        dest = jnp.where(i < count, dest_start + i, s - 1)  # [slots]
        rows = jnp.take(new_kv, src_idx, axis=2)  # [L,2,slots,KVD]
        # scatter along axis 2
        return cache.at[:, :, dest, :].set(rows)

    return commit


def make_logits_only(cfg: ModelConfig):
    """score(weights.., tokens i32[P]) -> logits [P,V] without cache I/O.

    Used by evaluation tooling (perplexity over a window) — full causal.
    """
    raise NotImplementedError  # reserved; evaluation uses prefill's logits
