"""Canonical lookahead token layout and attention-mask construction.

This module is the *layout canon*: `rust/src/layout/` re-implements the same
functions and both are cross-checked against `artifacts/layout_golden.json`
(emitted by aot.py) so the Python-lowered executables and the Rust coordinator
can never drift apart.

Layout of the `T_in = (W+G)*(N-1)` step-input tokens (DESIGN.md §1):

  index 0 .. W*(N-1)-1            lookahead block, row-major:
                                  idx = r*W + c, r in [0,N-2], c in [0,W-1]
                                  relative position = r + c
                                  (r=0,c=0) is the current token (relpos 0)
  index W*(N-1) .. T_in-1         verify block, candidate-major:
                                  idx = W*(N-1) + i*(N-1) + j,
                                  i in [0,G-1], j in [0,N-2]
                                  relative position = 1 + j

Visibility (intra-step; every token additionally sees cache keys < cache_len):

  lookahead (r,c) -> (r',c'):  (c'==c and r'<=r)  or  (r'==0 and c'<c)
  verify (i,j)    -> current token (0,0); (i',j') iff i'==i and j'<=j
  lookahead <-/-> verify otherwise; candidates mutually invisible.
"""

import numpy as np


def t_in(w: int, n: int, g: int) -> int:
    return (w + g) * (n - 1)


def n_lookahead(w: int, n: int) -> int:
    return w * (n - 1)


def descriptors(w: int, n: int, g: int):
    """Per-index descriptor arrays (branch, row, col, relpos), int32.

    branch: 0 = lookahead, 1 = verify.
    For lookahead: row=r, col=c.  For verify: row=i (candidate), col=j.
    """
    total = t_in(w, n, g)
    branch = np.zeros(total, dtype=np.int32)
    row = np.zeros(total, dtype=np.int32)
    col = np.zeros(total, dtype=np.int32)
    relpos = np.zeros(total, dtype=np.int32)
    idx = 0
    for r in range(n - 1):
        for c in range(w):
            branch[idx] = 0
            row[idx] = r
            col[idx] = c
            relpos[idx] = r + c
            idx += 1
    for i in range(g):
        for j in range(n - 1):
            branch[idx] = 1
            row[idx] = i
            col[idx] = j
            relpos[idx] = 1 + j
            idx += 1
    assert idx == total
    return branch, row, col, relpos


def visible(bq, rq, cq, bk, rk, ck) -> bool:
    """Scalar visibility rule between intra-step tokens (see module doc)."""
    if bq == 0 and bk == 0:
        return (ck == cq and rk <= rq) or (rk == 0 and ck < cq)
    if bq == 1 and bk == 1:
        return rk == rq and ck <= cq
    if bq == 1 and bk == 0:
        return rk == 0 and ck == 0  # the current token only
    return False  # lookahead never sees verify


def intra_mask(w: int, n: int, g: int) -> np.ndarray:
    """Dense bool [T_in, T_in] intra-step visibility mask (True = visible)."""
    b, r, c, _ = descriptors(w, n, g)
    total = len(b)
    m = np.zeros((total, total), dtype=bool)
    for qi in range(total):
        for ki in range(total):
            m[qi, ki] = visible(b[qi], r[qi], c[qi], b[ki], r[ki], c[ki])
    return m


def intra_mask_vectorized(w: int, n: int, g: int) -> np.ndarray:
    """Vectorized equivalent of intra_mask (used inside jitted models and the
    pallas kernel: the same expression evaluates on descriptor *blocks*)."""
    b, r, c, _ = descriptors(w, n, g)
    bq, bk = b[:, None], b[None, :]
    rq, rk = r[:, None], r[None, :]
    cq, ck = c[:, None], c[None, :]
    la = (bq == 0) & (bk == 0) & (((ck == cq) & (rk <= rq)) | ((rk == 0) & (ck < cq)))
    vv = (bq == 1) & (bk == 1) & (rk == rq) & (ck <= cq)
    vc = (bq == 1) & (bk == 0) & (rk == 0) & (ck == 0)
    return la | vv | vc


def relative_positions(w: int, n: int, g: int) -> np.ndarray:
    return descriptors(w, n, g)[3]


def linear_descriptors(k: int):
    """Descriptors for a plain causal chain of k tokens (AR / verify-only)."""
    branch = np.zeros(k, dtype=np.int32)
    row = np.zeros(k, dtype=np.int32)
    col = np.arange(k, dtype=np.int32)
    relpos = np.arange(k, dtype=np.int32)
    return branch, row, col, relpos


def linear_mask(k: int) -> np.ndarray:
    """Lower-triangular causal mask for a k-token chain."""
    i = np.arange(k)
    return i[None, :] <= i[:, None]


def golden_record(w: int, n: int, g: int) -> dict:
    """JSON-serializable golden record for cross-checking with Rust."""
    b, r, c, p = descriptors(w, n, g)
    m = intra_mask(w, n, g)
    # Pack mask rows as little-endian bit strings to keep the file small.
    packed = ["".join("1" if x else "0" for x in rowv) for rowv in m]
    return {
        "w": w,
        "n": n,
        "g": g,
        "t_in": int(t_in(w, n, g)),
        "branch": b.tolist(),
        "row": r.tolist(),
        "col": c.tolist(),
        "relpos": p.tolist(),
        "mask_rows": packed,
    }
