"""AOT pipeline: train models, lower every executable to HLO *text*, and emit
the manifest the Rust runtime binds against.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (all under `artifacts/`, gitignored, built by `make artifacts`):

  manifest.json          executable registry + weight binding contract
  weights_<model>.npz    trained parameters (np.savez, stored entries)
  <model>_<exe>.hlo.txt  one HLO module per executable
  layout_golden.json     mask/layout canon cross-check data for Rust tests
  workloads.json         deterministic eval prompt suites
  train_log.json         loss curves (EXPERIMENTS.md provenance)

Python runs ONCE, at build time. The Rust binary is self-contained after
`make artifacts`.
"""

import argparse
import json
import os
import sys
import time

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from compile import corpus, masks, model, train
from compile.config import (BOS_ID, EOS_ID, GENERIC_T_PAD, HEADLINE_CONFIGS,
                            LINEAR_LENS, MODELS, PAD_ID, PREFILL_LEN,
                            VOCAB_PADDED, VOCAB_SIZE, LookaheadConfig)
from compile.kernels import lookahead_attn

COMMIT_SLOTS = 16  # supports N up to 16 (Tab. 3 sweeps N=10)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default print elides big literals as
    # '{...}', which the 0.5.1 text parser accepts *silently* and turns into
    # garbage — the baked lookahead masks were zeroed without it.
    return comp.as_hlo_text(print_large_constants=True)


def lower_to_file(fn, arg_specs, path: str) -> int:
    # keep_unused=True: the positional parameter list is a binding contract
    # with the Rust runtime — jax must not DCE unused args (e.g. prefill's
    # n_valid) out of the HLO signature.
    lowered = jax.jit(fn, keep_unused=True).lower(*arg_specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def weight_specs(cfg):
    return [spec(s, np.float32) for s in model.weight_shapes(cfg)]


def cache_spec(cfg):
    kvd = cfg.n_kv_heads * cfg.head_dim
    return spec((cfg.n_layers, 2, model.cache_rows(cfg), kvd), np.float32)


def new_kv_spec(cfg, t):
    kvd = cfg.n_kv_heads * cfg.head_dim
    return spec((cfg.n_layers, 2, t, kvd), np.float32)


I32 = np.int32
SCALAR_I32 = spec((), I32)


def build_model_artifacts(name: str, out_dir: str, profile: str,
                          manifest: dict, log):
    cfg = MODELS[name]
    ws = weight_specs(cfg)
    cs = cache_spec(cfg)
    exes = {}
    t_commit = set()

    def emit(exe_name, fn, specs, meta):
        fname = f"{name}_{exe_name}.hlo.txt"
        t0 = time.time()
        nbytes = lower_to_file(fn, specs, os.path.join(out_dir, fname))
        log(f"  lowered {fname:44s} {nbytes/1024:8.1f} KiB "
            f"({time.time()-t0:.1f}s)")
        exes[exe_name] = {"file": fname, **meta}

    # --- prefill ---------------------------------------------------------
    emit("prefill", model.make_prefill(cfg, PREFILL_LEN),
         ws + [spec((PREFILL_LEN,), I32), SCALAR_I32],
         {"kind": "prefill", "prompt_len": PREFILL_LEN})

    # --- linear decode (AR / spec-verify / jacobi / prompt-lookup) -------
    lin_lens = LINEAR_LENS + ([16] if profile == "full" else [])
    if name == "draft":
        lin_lens = [1, 5]
    for k in lin_lens:
        emit(f"decode_lin_{k}", model.make_decode_linear(cfg, k),
             ws + [cs, SCALAR_I32, spec((k,), I32)],
             {"kind": "decode_lin", "k": k, "t_in": k})
        t_commit.add(k)

    # --- specialized lookahead decode -------------------------------------
    if name != "draft":
        la_configs = HEADLINE_CONFIGS if profile == "full" else \
            [LookaheadConfig(5, 3, 5)]
        if name == "small" and profile == "full":
            la_configs = HEADLINE_CONFIGS[:3]
        for lc in la_configs:
            emit(f"decode_la_{lc.tag}",
                 model.make_decode_specialized(cfg, lc.w, lc.n, lc.g),
                 ws + [cs, SCALAR_I32, spec((lc.t_in,), I32)],
                 {"kind": "decode_la", **lc.to_dict(), "attn": "jnp"})
            t_commit.add(lc.t_in)

        # pallas (L1) variant: always the cheap config; headline in full.
        pallas_cfgs = [LookaheadConfig(5, 3, 5)]
        if profile == "full" and name == "tiny":
            pallas_cfgs.append(LookaheadConfig(15, 5, 15))
        for lc in pallas_cfgs:
            emit(f"decode_la_{lc.tag}_pallas",
                 model.make_decode_specialized(cfg, lc.w, lc.n, lc.g,
                                               attn_impl="pallas"),
                 ws + [cs, SCALAR_I32, spec((lc.t_in,), I32)],
                 {"kind": "decode_la", **lc.to_dict(), "attn": "pallas"})
            t_commit.add(lc.t_in)

        # --- generic masked decode (sweeps) -------------------------------
        t_pads = GENERIC_T_PAD if profile == "full" else GENERIC_T_PAD[:1]
        for tp in t_pads:
            emit(f"decode_gen_{tp}", model.make_decode_generic(cfg, tp),
                 ws + [cs, SCALAR_I32, spec((tp,), I32), spec((tp,), I32),
                       spec((tp, tp), np.uint8)],
                 {"kind": "decode_gen", "t_pad": tp, "t_in": tp})
            t_commit.add(tp)

    # --- commit (one per distinct T_in) -----------------------------------
    for t in sorted(t_commit):
        emit(f"commit_{t}", model.make_commit(cfg, t, COMMIT_SLOTS),
             [cs, new_kv_spec(cfg, t), spec((COMMIT_SLOTS,), I32),
              SCALAR_I32, SCALAR_I32],
             {"kind": "commit", "t_in": t, "slots": COMMIT_SLOTS})

    kvd = cfg.n_kv_heads * cfg.head_dim
    manifest["models"][name] = {
        "config": cfg.to_dict(),
        "weights_file": f"weights_{name}.npz",
        "weight_names": model.weight_names(cfg),
        "weight_shapes": [list(s) for s in model.weight_shapes(cfg)],
        "cache_shape": [cfg.n_layers, 2, model.cache_rows(cfg), kvd],
        "junk_row": model.cache_rows(cfg) - 1,
        "executables": exes,
    }


def build_layout_golden(path: str):
    configs = [(5, 3, 5), (15, 5, 15), (10, 5, 10), (7, 5, 7), (2, 2, 1),
               (1, 5, 30), (5, 15, 15), (8, 3, 8), (4, 4, 2)]
    records = [masks.golden_record(w, n, g) for (w, n, g) in configs]
    with open(path, "w") as f:
        json.dump({"records": records}, f)


def l1_perf_report(manifest: dict):
    """Static L1 perf estimates (no TPU on this image — DESIGN.md §3)."""
    report = {}
    for lc in HEADLINE_CONFIGS:
        t = lc.t_in
        report[lc.tag] = {
            "vmem": lookahead_attn.vmem_estimate_bytes(t, d=32, s=768),
            "mxu": lookahead_attn.mxu_utilization_estimate(t, d=32, s=768),
        }
    manifest["l1_perf_estimates"] = report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--profile", default=os.environ.get(
        "ARTIFACT_PROFILE", "full"), choices=["full", "min"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--models", default=None,
                    help="comma list; default: tiny,small,draft (full) "
                         "or tiny,draft (min)")
    args = ap.parse_args()

    out = args.out_dir
    os.makedirs(out, exist_ok=True)
    stamp = os.path.join(out, "manifest.json")
    if os.path.exists(stamp) and not args.force:
        with open(stamp) as f:
            if json.load(f).get("profile") == args.profile:
                print(f"artifacts up to date ({args.profile}); use --force "
                      "to rebuild")
                return

    def log(msg):
        print(msg, flush=True)

    model_names = (args.models.split(",") if args.models else
                   (["tiny", "small", "draft"] if args.profile == "full"
                    else ["tiny", "draft"]))

    t0 = time.time()
    manifest = {
        "version": 1,
        "profile": args.profile,
        "vocab": {"size": VOCAB_SIZE, "padded": VOCAB_PADDED,
                  "pad": PAD_ID, "bos": BOS_ID, "eos": EOS_ID},
        "prefill_len": PREFILL_LEN,
        "commit_slots": COMMIT_SLOTS,
        "models": {},
    }

    # 1. train + save weights
    train_logs = {}
    for name in model_names:
        npz = os.path.join(out, f"weights_{name}.npz")
        if os.path.exists(npz) and not args.force:
            log(f"[aot] weights for {name} exist, skipping training")
            train_logs[name] = "cached"
            continue
        log(f"[aot] training {name} "
            f"({MODELS[name].param_count()/1e6:.2f}M params)...")
        train_logs[name] = train.train_and_save(name, npz, profile=args.profile)
    with open(os.path.join(out, "train_log.json"), "w") as f:
        json.dump(train_logs, f, indent=1)

    # 2. lower executables
    for name in model_names:
        log(f"[aot] lowering executables for {name}")
        build_model_artifacts(name, out, args.profile, manifest, log)

    # 3. canon + workloads + perf estimates
    build_layout_golden(os.path.join(out, "layout_golden.json"))
    corpus.write_workloads(os.path.join(out, "workloads.json"))
    l1_perf_report(manifest)

    manifest["build_seconds"] = round(time.time() - t0, 1)
    with open(stamp, "w") as f:
        json.dump(manifest, f, indent=1)
    log(f"[aot] done in {manifest['build_seconds']}s -> {stamp}")


if __name__ == "__main__":
    sys.exit(main())
