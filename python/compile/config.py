"""Model and lookahead configuration shared across the compile pipeline.

These dataclasses are the single source of truth for every AOT artifact:
`aot.py` serializes them into `artifacts/manifest.json`, which the Rust
runtime parses to bind executables, weights, and shapes.
"""

from dataclasses import dataclass, field, asdict


# Byte-level vocabulary: 256 raw bytes + specials.
VOCAB_BYTES = 256
PAD_ID = 256
BOS_ID = 257
EOS_ID = 258
VOCAB_SIZE = 259
# Round up to a multiple of 8 for MXU-friendly output projections.
VOCAB_PADDED = 264


@dataclass(frozen=True)
class ModelConfig:
    """LLaMA-style byte transformer dimensions."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    max_seq: int  # KV-cache capacity (committed tokens)
    vocab: int = VOCAB_PADDED
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        d, f, l = self.d_model, self.d_ff, self.n_layers
        per_layer = 2 * d  # two RMSNorm gains
        per_layer += d * d + 2 * d * (self.n_kv_heads * self.head_dim) + d * d  # qkvo
        per_layer += 3 * d * f  # SwiGLU (gate, up, down)
        return self.vocab * d + l * per_layer + d  # embed (tied head) + final norm

    def to_dict(self) -> dict:
        out = asdict(self)
        out["head_dim"] = self.head_dim
        out["params"] = self.param_count()
        return out


@dataclass(frozen=True)
class LookaheadConfig:
    """(W, N, G) — window size, n-gram size, max verification candidates."""

    w: int
    n: int
    g: int

    def __post_init__(self):
        assert self.n >= 2, "n-gram size must be >= 2"
        assert self.w >= 1 and self.g >= 0

    @property
    def t_in(self) -> int:
        """Per-step input tokens: lookahead (N-1 rows x W) + verify G x (N-1)."""
        return (self.w + self.g) * (self.n - 1)

    @property
    def n_lookahead(self) -> int:
        return self.w * (self.n - 1)

    @property
    def tag(self) -> str:
        return f"w{self.w}n{self.n}g{self.g}"

    def to_dict(self) -> dict:
        return {
            "w": self.w,
            "n": self.n,
            "g": self.g,
            "t_in": self.t_in,
            "n_lookahead": self.n_lookahead,
            "tag": self.tag,
        }


# ---------------------------------------------------------------------------
# Model zoo. Sized for a single-core CPU PJRT testbed (see DESIGN.md §2):
# `tiny` is the default experiment model, `small` the scaling point,
# `draft` is the speculative-decoding draft model.
# ---------------------------------------------------------------------------

MODELS = {
    "tiny": ModelConfig(
        name="tiny", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=352, max_seq=512,
    ),
    "small": ModelConfig(
        name="small", n_layers=4, d_model=192, n_heads=6, n_kv_heads=6,
        d_ff=512, max_seq=512,
    ),
    "draft": ModelConfig(
        name="draft", n_layers=1, d_model=64, n_heads=2, n_kv_heads=2,
        d_ff=176, max_seq=512,
    ),
}

# Prompt capacity of the prefill executable (prompts are right-padded to this).
PREFILL_LEN = 256

# Max tokens a single step may commit = N_max accepted tokens. The commit
# executable is built per (model, t_in) pair with this many scatter slots.
def commit_slots(n: int) -> int:
    return n


# Lookahead configs compiled as *specialized* artifacts (hardcoded pattern /
# pallas path). The generic (mask-as-input) executable covers sweeps.
HEADLINE_CONFIGS = [
    LookaheadConfig(15, 5, 15),  # paper Tab. 4, 7B row
    LookaheadConfig(10, 5, 10),  # paper Tab. 4, 13B row
    LookaheadConfig(7, 5, 7),    # paper Tab. 4, 34B row
    LookaheadConfig(5, 3, 5),    # cheap default for tests
]

# Linear-chain decode lengths (plain causal over K new tokens):
#   1 -> autoregressive; 5 -> speculative-decoding verification (gamma=4);
#   8 -> prompt-lookup verification.
LINEAR_LENS = [1, 5, 8]

# Padded T_in sizes for the generic masked decode executable.
GENERIC_T_PAD = [16, 32, 64, 128, 256]
