"""Pallas lookahead-attention kernel (L1).

The paper hardcodes the lookahead attention pattern (Fig. 2b) into CUDA
FlashAttention. This is the TPU/Pallas rethink (DESIGN.md §3):

- flash-style **online softmax**: one pass over the committed KV-cache prefix
  in `Bk`-sized blocks, then one pass over the intra-step keys — the
  `T_in x (S + T_in)` score matrix is never materialized in HBM;
- the lookahead visibility pattern is **computed, not stored**: per-index
  descriptor vectors (branch, row, col — three `int32[T_in]` constants that
  live in VMEM) are compared with integer arithmetic inside the kernel, so
  there is no `T x T` mask in the memory traffic at all;
- tiles are MXU-shaped: `(Bq, D) x (D, Bk)` dots with fp32 accumulation.

`interpret=True` is mandatory on this CPU-only image — real Mosaic lowering
emits TPU custom-calls the CPU PJRT plugin cannot execute. Correctness is
checked against `ref.attention_ref` by `python/tests/test_kernel.py`.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from compile import masks

NEG_INF = -1e30


def _q_block(t: int) -> int:
    """Largest MXU-friendly query tile that divides T."""
    for bq in (16, 8, 4, 2, 1):
        if t % bq == 0:
            return bq
    return 1


def _kernel(
    # refs (per grid step): q [Bq,1,D], new kv [T,1,D], cache kv [S,1,D],
    # descriptor vectors int32[T] (the hardcoded pattern lives in these)
    cl_ref, q_ref, kn_ref, vn_ref, kc_ref, vc_ref, db_ref, dr_ref, dc_ref,
    o_ref,
    *, bq: int, bk: int, t: int, s: int, scale: float,
):
    qb = pl.program_id(1)
    cache_len = cl_ref[0]
    desc_b, desc_r, desc_c = db_ref[...], dr_ref[...], dc_ref[...]

    q = q_ref[:, 0, :].astype(jnp.float32) * scale  # [Bq, D]
    d = q.shape[-1]

    m_i = jnp.full((bq,), NEG_INF, dtype=jnp.float32)
    l_i = jnp.zeros((bq,), dtype=jnp.float32)
    acc = jnp.zeros((bq, d), dtype=jnp.float32)

    # ---- phase 1: committed prefix (visibility = column < cache_len) ------
    def cache_step(i, carry):
        m_i, l_i, acc = carry
        k = kc_ref[pl.ds(i * bk, bk), 0, :].astype(jnp.float32)  # [Bk, D]
        v = vc_ref[pl.ds(i * bk, bk), 0, :].astype(jnp.float32)
        sc = q @ k.T  # [Bq, Bk] — MXU tile
        col = i * bk + jax.lax.iota(jnp.int32, bk)
        sc = jnp.where((col < cache_len)[None, :], sc, NEG_INF)
        m_new = jnp.maximum(m_i, sc.max(axis=-1))
        p = jnp.exp(sc - m_new[:, None])
        corr = jnp.exp(m_i - m_new)
        l_new = l_i * corr + p.sum(axis=-1)
        acc_new = acc * corr[:, None] + p @ v
        return m_new, l_new, acc_new

    m_i, l_i, acc = jax.lax.fori_loop(0, s // bk, cache_step, (m_i, l_i, acc))

    # ---- phase 2: intra-step keys (hardcoded lookahead pattern) -----------
    qrows = qb * bq + jax.lax.iota(jnp.int32, bq)
    bq_d, rq_d, cq_d = desc_b[qrows], desc_r[qrows], desc_c[qrows]
    bk_d, rk_d, ck_d = desc_b, desc_r, desc_c  # all T intra keys at once

    # The visibility rule from masks.py, evaluated on the descriptor tiles.
    bqx, bkx = bq_d[:, None], bk_d[None, :]
    rqx, rkx = rq_d[:, None], rk_d[None, :]
    cqx, ckx = cq_d[:, None], ck_d[None, :]
    la = (bqx == 0) & (bkx == 0) & (
        ((ckx == cqx) & (rkx <= rqx)) | ((rkx == 0) & (ckx < cqx)))
    vv = (bqx == 1) & (bkx == 1) & (rkx == rqx) & (ckx <= cqx)
    vc = (bqx == 1) & (bkx == 0) & (rkx == 0) & (ckx == 0)
    vis = la | vv | vc  # [Bq, T]

    k = kn_ref[:, 0, :].astype(jnp.float32)  # [T, D]
    v = vn_ref[:, 0, :].astype(jnp.float32)
    sc = q @ k.T  # [Bq, T]
    sc = jnp.where(vis, sc, NEG_INF)
    m_new = jnp.maximum(m_i, sc.max(axis=-1))
    p = jnp.exp(sc - m_new[:, None])
    corr = jnp.exp(m_i - m_new)
    l_i = l_i * corr + p.sum(axis=-1)
    acc = acc * corr[:, None] + p @ v

    out = acc / jnp.maximum(l_i, 1e-30)[:, None]
    o_ref[:, 0, :] = out.astype(o_ref.dtype)


def lookahead_attention(
    q: jnp.ndarray,        # [T, H, D]
    k_new: jnp.ndarray,    # [T, Hk, D]
    v_new: jnp.ndarray,    # [T, Hk, D]
    k_cache: jnp.ndarray,  # [S, Hk, D]
    v_cache: jnp.ndarray,  # [S, Hk, D]
    cache_len: jnp.ndarray,  # scalar int32
    w: int, n: int, g: int,
    *, bk: int = 128,
) -> jnp.ndarray:
    """Flash-style attention with the (W,N,G) lookahead pattern hardcoded."""
    t, h, d = q.shape
    s, hk, _ = k_cache.shape
    assert t == masks.t_in(w, n, g), (t, w, n, g)
    assert s % bk == 0, f"cache rows {s} must be a multiple of Bk={bk}"
    group = h // hk

    b_np, r_np, c_np, _ = masks.descriptors(w, n, g)

    bq = _q_block(t)
    grid = (h, t // bq)

    kernel = functools.partial(
        _kernel, bq=bq, bk=bk, t=t, s=s, scale=1.0 / float(np.sqrt(d)),
    )

    full_t = pl.BlockSpec((t,), lambda hh, qq: (0,))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda hh, qq: (0,)),                 # cache_len
            pl.BlockSpec((bq, 1, d), lambda hh, qq: (qq, hh, 0)),    # q
            pl.BlockSpec((t, 1, d), lambda hh, qq: (0, hh // group, 0)),   # k_new
            pl.BlockSpec((t, 1, d), lambda hh, qq: (0, hh // group, 0)),   # v_new
            pl.BlockSpec((s, 1, d), lambda hh, qq: (0, hh // group, 0)),   # k_cache
            pl.BlockSpec((s, 1, d), lambda hh, qq: (0, hh // group, 0)),   # v_cache
            full_t, full_t, full_t,                                  # descriptors
        ],
        out_specs=pl.BlockSpec((bq, 1, d), lambda hh, qq: (qq, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((t, h, d), q.dtype),
        interpret=True,  # CPU-only image: Mosaic custom-calls are unloadable
    )(cache_len.reshape(1).astype(jnp.int32), q, k_new, v_new, k_cache,
      v_cache, jnp.asarray(b_np), jnp.asarray(r_np), jnp.asarray(c_np))


def vmem_estimate_bytes(t: int, d: int, s: int, bq: int = None, bk: int = 128,
                        bytes_per_el: int = 4) -> dict:
    """Static VMEM working-set estimate per grid step (DESIGN.md §3).

    Used by the L1 perf report (no real TPU on this image): q tile + two KV
    tiles + score tile + softmax state + accumulator + descriptor vectors.
    """
    bq = bq or _q_block(t)
    els = {
        "q_tile": bq * d,
        "kv_tile": 2 * max(bk, t) * d,
        "score_tile": bq * max(bk, t),
        "softmax_state": 2 * bq,
        "accumulator": bq * d,
        "descriptors": 3 * t,  # int32
    }
    total = sum(els.values()) * bytes_per_el
    els_bytes = {k: v * bytes_per_el for k, v in els.items()}
    els_bytes["total"] = total
    els_bytes["fits_16MiB_vmem"] = total <= 16 * 1024 * 1024
    return els_bytes


def mxu_utilization_estimate(t: int, d: int, s: int, bq: int = None,
                             bk: int = 128) -> dict:
    """Fraction of issued MXU work that is useful, given tile shapes.

    The 128x128 MXU is fed (Bq, D) x (D, Bk) tiles; utilization is the
    product of the fill ratios of each dimension, per phase.
    """
    bq = bq or _q_block(t)

    def fill(x, unit=128):
        return min(x, unit) / unit

    phase1 = fill(bq) * fill(d) * fill(bk)
    phase2 = fill(bq) * fill(d) * fill(t)
    # Weight phases by their MAC counts.
    macs1 = s * d * t  # full prefix pass
    macs2 = t * d * t
    util = (phase1 * macs1 + phase2 * macs2) / (macs1 + macs2)
    return {"bq": bq, "bk": bk, "phase_prefix": phase1,
            "phase_intra": phase2, "weighted": util}
