"""Pure-jnp attention oracle for the lookahead decoding step.

This is the correctness reference for the Pallas kernel
(`lookahead_attn.py`): full materialized-mask attention over
[KV cache prefix ++ intra-step tokens]. Everything here is deliberately
simple and dense — it exists to be obviously right.
"""

import jax.numpy as jnp
import numpy as np


def attention_ref(
    q: jnp.ndarray,        # [T, H, D]   queries (RoPE already applied)
    k_new: jnp.ndarray,    # [T, Hk, D]  this step's keys (RoPE applied)
    v_new: jnp.ndarray,    # [T, Hk, D]
    k_cache: jnp.ndarray,  # [S, Hk, D]  committed keys
    v_cache: jnp.ndarray,  # [S, Hk, D]
    cache_len: jnp.ndarray,   # scalar int32: valid cache rows
    intra_mask: jnp.ndarray,  # [T, T] bool: intra-step visibility
) -> jnp.ndarray:          # [T, H, D]
    t, h, d = q.shape
    s, hk, _ = k_cache.shape
    assert h % hk == 0
    group = h // hk

    def expand(x):  # GQA: expand KV heads to query heads
        return jnp.repeat(x, group, axis=1)

    full_k = jnp.concatenate([expand(k_cache), expand(k_new)], axis=0)  # [S+T,H,D]
    full_v = jnp.concatenate([expand(v_cache), expand(v_new)], axis=0)

    scale = 1.0 / np.sqrt(d)
    scores = jnp.einsum("thd,shd->hts", q.astype(jnp.float32),
                        full_k.astype(jnp.float32)) * scale  # [H,T,S+T]

    cache_visible = jnp.arange(s)[None, :] < cache_len  # [1, S]
    cache_visible = jnp.broadcast_to(cache_visible, (t, s))
    mask = jnp.concatenate([cache_visible, intra_mask], axis=1)  # [T, S+T]

    scores = jnp.where(mask[None, :, :], scores, -1e30)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / jnp.maximum(probs.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("hts,shd->thd", probs, full_v.astype(jnp.float32))
    return out.astype(q.dtype)
