"""Build-time trainer for the byte-level transformer zoo.

Trains each model on the deterministic synthetic corpus mixture with Adam and
next-byte cross-entropy, entirely in JAX on the CPU. Weights are saved as
`.npz` (read natively by the Rust `xla` crate) and the loss curve goes to
`artifacts/train_log.json` (surfaced in EXPERIMENTS.md).

This is a *substrate*, not the paper's contribution — it exists so the served
model is a real trained model rather than random weights, giving the n-gram
pool realistic hit statistics.
"""

import json
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from compile import corpus, model
from compile.config import BOS_ID, MODELS, VOCAB_BYTES, ModelConfig


def encode_bytes(data: bytes) -> np.ndarray:
    return np.frombuffer(data, dtype=np.uint8).astype(np.int32)


def make_batches(data: np.ndarray, batch: int, seq: int, steps: int,
                 seed: int = 0):
    rng = np.random.RandomState(seed)
    n = len(data) - seq - 1
    for _ in range(steps):
        idx = rng.randint(0, n, size=batch)
        x = np.stack([data[i:i + seq] for i in idx])
        y = np.stack([data[i + 1:i + seq + 1] for i in idx])
        yield x, y


def _causal_forward(cfg: ModelConfig, weights, tokens):
    """Batched full-causal forward for training. tokens: [B, T] -> logits."""
    embed, layers, final_norm = model._split_weights(cfg, weights)
    b, t = tokens.shape
    positions = jnp.arange(t, dtype=jnp.int32)
    intra = jnp.tril(jnp.ones((t, t), dtype=bool))
    kvd = cfg.n_kv_heads * cfg.head_dim
    empty_k = jnp.zeros((0, cfg.n_kv_heads, cfg.head_dim), dtype=jnp.float32)

    def one(seq_tokens):
        x = embed[seq_tokens]
        zero = jnp.asarray(0, dtype=jnp.int32)
        for lw in layers:
            x, _, _ = model._layer(cfg, lw, x, positions, empty_k, empty_k,
                                   zero, intra, "jnp", None)
        x = model.rmsnorm(x, final_norm, cfg.norm_eps)
        return (x @ embed.T).astype(jnp.float32)

    return jax.vmap(one)(tokens)


def loss_fn(cfg: ModelConfig, weights, x, y):
    logits = _causal_forward(cfg, weights, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)
    return nll.mean()


def adam_init(weights):
    return ([jnp.zeros_like(w) for w in weights],
            [jnp.zeros_like(w) for w in weights])


def adam_step(weights, grads, m, v, step, lr, b1=0.9, b2=0.99, eps=1e-8):
    new_w, new_m, new_v = [], [], []
    t = step + 1
    for w, gr, mi, vi in zip(weights, grads, m, v):
        mi = b1 * mi + (1 - b1) * gr
        vi = b2 * vi + (1 - b2) * jnp.square(gr)
        mhat = mi / (1 - b1 ** t)
        vhat = vi / (1 - b2 ** t)
        new_w.append(w - lr * mhat / (jnp.sqrt(vhat) + eps))
        new_m.append(mi)
        new_v.append(vi)
    return new_w, new_m, new_v


def train_model(cfg: ModelConfig, steps: int, batch: int, seq: int,
                lr: float = 3e-3, corpus_bytes: int = 400_000,
                seed: int = 0, log_every: int = 10):
    data = encode_bytes(corpus.training_corpus(corpus_bytes, seed=seed))
    weights = [jnp.asarray(w) for w in model.init_weights(cfg, seed=seed)]
    m, v = adam_init(weights)

    @jax.jit
    def step_fn(weights, m, v, step, x, y):
        loss, grads = jax.value_and_grad(
            lambda ws: loss_fn(cfg, ws, x, y))(weights)
        weights, m, v = adam_step(weights, grads, m, v, step, lr)
        return weights, m, v, loss

    log = []
    t0 = time.time()
    for i, (x, y) in enumerate(make_batches(data, batch, seq, steps,
                                            seed=seed + 1)):
        weights, m, v, loss = step_fn(weights, m, v, i,
                                      jnp.asarray(x), jnp.asarray(y))
        if i % log_every == 0 or i == steps - 1:
            log.append({"step": i, "loss": float(loss),
                        "elapsed_s": round(time.time() - t0, 2)})
            print(f"[train:{cfg.name}] step {i:4d} loss {float(loss):.4f}")
    return [np.asarray(w) for w in weights], log


def save_weights(path: str, cfg: ModelConfig, weights: List[np.ndarray]):
    arrays = {name: w for name, w in zip(model.weight_names(cfg), weights)}
    # np.savez keys cannot contain '/', '.' is fine; store uncompressed so the
    # Rust side's stored-entry zip reader path stays simple.
    np.savez(path, **arrays)


TRAIN_PLANS = {
    # name: (steps, batch, seq, corpus_bytes)
    "tiny": (240, 12, 128, 400_000),
    "small": (160, 8, 128, 400_000),
    "draft": (160, 12, 128, 400_000),
}

MIN_PLAN = (30, 4, 96, 120_000)  # ARTIFACT_PROFILE=min (tests / CI)


def train_and_save(name: str, out_npz: str, profile: str = "full"):
    cfg = MODELS[name]
    steps, batch, seq, nbytes = (
        MIN_PLAN if profile == "min" else TRAIN_PLANS[name])
    weights, log = train_model(cfg, steps=steps, batch=batch, seq=seq,
                               corpus_bytes=nbytes)
    save_weights(out_npz, cfg, weights)
    return log
