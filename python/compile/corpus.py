"""Deterministic synthetic corpora + evaluation workload suites.

Substitutes for the paper's datasets (DESIGN.md §2): each suite mimics the
statistical property the paper leans on —

  code       ~ HumanEval / MBPP   : template-heavy, repetitive -> high S
  class-code ~ ClassEval          : long class completions      -> highest S
  chat       ~ MT-Bench           : diverse wording             -> lower S
  math       ~ GSM8K              : structured arithmetic       -> medium S
  summarize  ~ XSum / CNN-DM      : article + TL;DR             -> medium S

The same generator builds (a) the training corpus mixture and (b) the eval
prompt suites serialized to `artifacts/workloads.json`, so Rust never has to
reproduce the templates. Everything is seeded -> byte-reproducible.
"""

import json

import numpy as np

NOUNS = ["queue", "cache", "token", "batch", "model", "server", "stream",
         "buffer", "window", "branch", "worker", "client", "tensor", "router"]
VERBS = ["builds", "checks", "drains", "emits", "holds", "loads", "merges",
         "parses", "routes", "runs", "sends", "sorts", "splits", "tracks"]
ADJS = ["fast", "lazy", "small", "stale", "warm", "spare", "dense", "flat"]
FUNCS = ["add", "sub", "mul", "mix", "cap", "pad", "clip", "norm"]
VARS = ["a", "b", "c", "x", "y", "z", "n", "m"]


def _pick(rng, xs):
    return xs[rng.randint(0, len(xs))]


# ---------------------------------------------------------------------------
# Per-suite text generators
# ---------------------------------------------------------------------------

def gen_code(rng: np.random.RandomState) -> str:
    f = _pick(rng, FUNCS)
    a, b = _pick(rng, VARS), _pick(rng, VARS)
    op = _pick(rng, ["+", "-", "*"])
    body = (
        f"def {f}_{a}{b}({a}, {b}):\n"
        f"    result = {a} {op} {b}\n"
        f"    return result\n\n"
    )
    loop = (
        f"for {a} in range(10):\n"
        f"    total = {f}_{a}{b}({a}, {a})\n"
        f"    print(total)\n\n"
    )
    return body + (loop if rng.rand() < 0.5 else "")


def gen_class_code(rng: np.random.RandomState) -> str:
    n1, n2 = _pick(rng, NOUNS), _pick(rng, NOUNS)
    f1, f2 = _pick(rng, FUNCS), _pick(rng, FUNCS)
    return (
        f"class {n1.capitalize()}{n2.capitalize()}:\n"
        f"    def __init__(self, size):\n"
        f"        self.size = size\n"
        f"        self.items = []\n\n"
        f"    def {f1}(self, item):\n"
        f"        self.items.append(item)\n"
        f"        return len(self.items)\n\n"
        f"    def {f2}(self):\n"
        f"        return self.items.pop()\n\n"
    )


CHAT_Q = [
    "user: how does the {adj} {n1} work with the {n2}?\n",
    "user: why would a {n1} ever {v0} the {n2} twice?\n",
    "user: can you explain what happens when the {n2} gets {adj}?\n",
    "user: what is the difference between a {n1} and a {n2} here?\n",
    "user: my {n1} keeps dropping the {adj} {n2}, any idea why?\n",
]
CHAT_A = [
    "assistant: the {n1} {v0} each {n2} and keeps the {adj} ones. "
    "when the {n2} is full, the {n1} {v1} it again.\n\n",
    "assistant: usually the {n2} stays {adj} until the {n1} {v0} it. "
    "after that, a second {n1} {v1} whatever is left over.\n\n",
    "assistant: that depends on the {n1}. a {adj} one {v0} the {n2} "
    "right away, while a slower one only {v1} it on demand.\n\n",
    "assistant: think of the {n1} as the thing that {v0} and the {n2} "
    "as the thing being {adj}. they only meet when one {v1} the other.\n\n",
]


def gen_chat(rng: np.random.RandomState) -> str:
    subst = {
        "n1": _pick(rng, NOUNS), "n2": _pick(rng, NOUNS),
        "v0": _pick(rng, VERBS), "v1": _pick(rng, VERBS),
        "adj": _pick(rng, ADJS),
    }
    q = _pick(rng, CHAT_Q).format(**subst)
    a = _pick(rng, CHAT_A).format(**subst)
    return q + a


def gen_math(rng: np.random.RandomState) -> str:
    a, b = rng.randint(2, 50), rng.randint(2, 50)
    op = _pick(rng, ["+", "-", "*"])
    val = {"+": a + b, "-": a - b, "*": a * b}[op]
    return f"Q: what is {a} {op} {b}?\nA: {a} {op} {b} = {val}\n\n"


def gen_summarize(rng: np.random.RandomState) -> str:
    n1, n2 = _pick(rng, NOUNS), _pick(rng, NOUNS)
    v, adj = _pick(rng, VERBS), _pick(rng, ADJS)
    body = (f"article: the {adj} {n1} {v} the {n2} all day. "
            f"the {n2} stays {_pick(rng, ADJS)} while the {n1} {_pick(rng, VERBS)} it. "
            f"experts say the {n1} will keep the {n2} {adj}.\n")
    tldr = f"tl;dr: the {adj} {n1} {v} the {n2}.\n\n"
    return body + tldr


SUITES = {
    "code": gen_code,
    "class-code": gen_class_code,
    "chat": gen_chat,
    "math": gen_math,
    "summarize": gen_summarize,
}


# ---------------------------------------------------------------------------
# Training corpus
# ---------------------------------------------------------------------------

def training_corpus(n_bytes: int, seed: int = 0) -> bytes:
    """Deterministic suite mixture, at least n_bytes long."""
    rng = np.random.RandomState(seed)
    names = sorted(SUITES)
    chunks, total = [], 0
    while total < n_bytes:
        gen = SUITES[names[rng.randint(0, len(names))]]
        s = gen(rng).encode("utf-8")
        chunks.append(s)
        total += len(s)
    return b"".join(chunks)


# ---------------------------------------------------------------------------
# Evaluation workloads (prompt = first `prompt_frac` of a document; the
# trained model regenerates the rest — mirrors code-completion / chat tasks).
# ---------------------------------------------------------------------------

def eval_workloads(n_prompts: int = 24, seed: int = 7,
                   max_prompt: int = 192) -> dict:
    out = {}
    for name, gen in sorted(SUITES.items()):
        rng = np.random.RandomState(seed + hash(name) % 1000)
        prompts = []
        for _ in range(n_prompts):
            # 2-3 documents of context, then an opening fragment to complete.
            doc = "".join(gen(rng) for _ in range(rng.randint(2, 4)))
            frag = gen(rng)
            cut = max(8, int(len(frag) * 0.3))
            text = (doc + frag[:cut])[-max_prompt:]
            prompts.append(text)
        out[name] = prompts
    return out


def write_workloads(path: str, **kw) -> None:
    data = {
        "suites": eval_workloads(**kw),
        "note": "deterministic synthetic substitutes, see DESIGN.md §2",
    }
    with open(path, "w") as f:
        json.dump(data, f, indent=1)
