"""Layout/mask canon tests — the contract shared with rust/src/layout/."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import masks

WNG = st.tuples(st.integers(1, 12), st.integers(2, 6), st.integers(0, 12))


def test_t_in_formula():
    assert masks.t_in(15, 5, 15) == 120
    assert masks.t_in(5, 3, 5) == 20
    assert masks.t_in(1, 2, 0) == 1


def test_paper_figure2b_example():
    """W=5, N=4, G=2 — the worked example of Fig. 2(b): 'only the green token
    at position 5 and all orange tokens are visible to the red token 6'."""
    w, n, g = 5, 4, 2
    m = masks.intra_mask(w, n, g)
    b, r, c, p = masks.descriptors(w, n, g)

    def idx(rr, cc):  # lookahead index
        return rr * w + cc

    red6 = idx(2, 4)  # row 2 (newest), col 4 -> relpos 6
    assert p[red6] == 6
    visible = {i for i in range(masks.t_in(w, n, g)) if m[red6, i]}
    expected = {idx(0, cc) for cc in range(5)}  # all orange
    expected |= {idx(1, 4)}  # green token at position 5 (row 1, col 4)
    expected |= {red6}  # self
    assert visible == expected


def test_current_token_is_index0():
    b, r, c, p = masks.descriptors(7, 5, 7)
    assert b[0] == 0 and r[0] == 0 and c[0] == 0 and p[0] == 0


@settings(max_examples=40, deadline=None)
@given(WNG)
def test_vectorized_matches_scalar(wng):
    w, n, g = wng
    assert (masks.intra_mask(w, n, g)
            == masks.intra_mask_vectorized(w, n, g)).all()


@settings(max_examples=40, deadline=None)
@given(WNG)
def test_mask_invariants(wng):
    w, n, g = wng
    m = masks.intra_mask(w, n, g)
    b, r, c, p = masks.descriptors(w, n, g)
    t = masks.t_in(w, n, g)
    # every token sees itself
    assert m.diagonal().all()
    # visibility implies non-increasing relative position
    qi, ki = np.nonzero(m)
    assert (p[ki] <= p[qi]).all()
    # lookahead never sees verify, candidates are disjoint
    for q in range(t):
        for k in range(t):
            if m[q, k] and b[q] == 0:
                assert b[k] == 0
            if m[q, k] and b[q] == 1 and b[k] == 1:
                assert r[q] == r[k]


@settings(max_examples=20, deadline=None)
@given(WNG)
def test_diagonal_forms_contiguous_pseudo_sequence(wng):
    """For every lookahead token, its visible set must form a contiguous
    position range 0..relpos — the Jacobi trajectory property that makes the
    n-grams meaningful."""
    w, n, g = wng
    m = masks.intra_mask(w, n, g)
    b, r, c, p = masks.descriptors(w, n, g)
    nla = masks.n_lookahead(w, n)
    for q in range(nla):
        seen = sorted(p[k] for k in range(nla) if m[q, k])
        assert seen == list(range(p[q] + 1)), (q, seen)


def test_linear_mask_is_causal():
    m = masks.linear_mask(6)
    assert (m == np.tril(np.ones((6, 6), bool))).all()


def test_golden_record_roundtrip():
    rec = masks.golden_record(5, 3, 5)
    m = masks.intra_mask(5, 3, 5)
    for i, rowbits in enumerate(rec["mask_rows"]):
        assert [ch == "1" for ch in rowbits] == m[i].tolist()
