"""Corpus determinism + short-training smoke (loss must decrease)."""

import json

import numpy as np
import pytest

from compile import corpus, train
from compile.config import MODELS


def test_corpus_deterministic():
    a = corpus.training_corpus(50_000, seed=3)
    b = corpus.training_corpus(50_000, seed=3)
    assert a == b
    c = corpus.training_corpus(50_000, seed=4)
    assert a != c


def test_corpus_is_ascii_bytes():
    data = corpus.training_corpus(20_000, seed=0)
    assert max(data) < 128  # generators emit ASCII -> fits byte vocab


def test_all_suites_generate():
    rng = np.random.RandomState(0)
    for name, gen in corpus.SUITES.items():
        s = gen(rng)
        assert len(s) > 10, name


def test_eval_workloads_shape_and_determinism():
    w1 = corpus.eval_workloads(n_prompts=4, seed=9)
    w2 = corpus.eval_workloads(n_prompts=4, seed=9)
    assert w1 == w2
    assert set(w1) == set(corpus.SUITES)
    for suite, prompts in w1.items():
        assert len(prompts) == 4
        assert all(0 < len(p) <= 192 for p in prompts)


def test_batches_are_shifted_pairs():
    data = train.encode_bytes(corpus.training_corpus(30_000, seed=1))
    for x, y in train.make_batches(data, batch=2, seq=16, steps=3, seed=0):
        assert x.shape == y.shape == (2, 16)
        # y is x shifted by one position within the source stream
        assert (x[:, 1:] == y[:, :-1]).all()


@pytest.mark.slow
def test_training_reduces_loss():
    cfg = MODELS["draft"]
    _, log = train.train_model(cfg, steps=25, batch=4, seq=64,
                               corpus_bytes=60_000, log_every=5)
    first, last = log[0]["loss"], log[-1]["loss"]
    assert last < first * 0.8, (first, last)
