"""L1 correctness: Pallas lookahead-attention kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, dtypes, (W,N,G) configs, GQA group sizes, and
cache-fill levels; assert_allclose against `ref.attention_ref`.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import masks
from compile.kernels.lookahead_attn import (lookahead_attention,
                                            mxu_utilization_estimate,
                                            vmem_estimate_bytes)
from compile.kernels.ref import attention_ref


def run_pair(w, n, g, h, hk, d, s, cache_len, dtype, seed=0, bk=128):
    t = masks.t_in(w, n, g)
    rng = np.random.RandomState(seed)

    def arr(*shape):
        return jnp.asarray(rng.randn(*shape).astype(np.float32), dtype=dtype)

    q, kn, vn = arr(t, h, d), arr(t, hk, d), arr(t, hk, d)
    kc, vc = arr(s, hk, d), arr(s, hk, d)
    cl = jnp.asarray(cache_len, dtype=jnp.int32)
    intra = jnp.asarray(masks.intra_mask(w, n, g))
    ref = attention_ref(q, kn, vn, kc, vc, cl, intra)
    out = lookahead_attention(q, kn, vn, kc, vc, cl, w, n, g, bk=bk)
    return np.asarray(ref, np.float32), np.asarray(out, np.float32)


def tol(dtype):
    return dict(atol=5e-5, rtol=5e-5) if dtype == jnp.float32 \
        else dict(atol=5e-2, rtol=5e-2)


@settings(max_examples=25, deadline=None)
@given(
    wng=st.tuples(st.integers(1, 8), st.integers(2, 5), st.integers(0, 8)),
    heads=st.sampled_from([(4, 4), (4, 2), (2, 1)]),
    d=st.sampled_from([16, 32, 64]),
    cache_len=st.integers(0, 255),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_kernel_matches_ref(wng, heads, d, cache_len, dtype):
    w, n, g = wng
    h, hk = heads
    ref, out = run_pair(w, n, g, h, hk, d, 256, cache_len, dtype)
    np.testing.assert_allclose(ref, out, **tol(dtype))


@pytest.mark.parametrize("wng", [(15, 5, 15), (10, 5, 10), (7, 5, 7)])
def test_kernel_headline_configs(wng):
    ref, out = run_pair(*wng, h=4, hk=4, d=32, s=768, cache_len=300,
                        dtype=jnp.float32)
    np.testing.assert_allclose(ref, out, atol=5e-5, rtol=5e-5)


def test_kernel_empty_cache():
    ref, out = run_pair(5, 3, 5, h=4, hk=4, d=32, s=256, cache_len=0,
                        dtype=jnp.float32)
    np.testing.assert_allclose(ref, out, atol=5e-5, rtol=5e-5)


def test_kernel_single_token_window():
    """(W=1, N=2, G=0) degenerates to plain single-token decode."""
    ref, out = run_pair(1, 2, 0, h=2, hk=2, d=16, s=128, cache_len=17,
                        dtype=jnp.float32)
    np.testing.assert_allclose(ref, out, atol=5e-5, rtol=5e-5)


def test_kernel_different_bk():
    ref, out = run_pair(5, 3, 5, h=4, hk=4, d=32, s=256, cache_len=100,
                        dtype=jnp.float32, bk=64)
    np.testing.assert_allclose(ref, out, atol=5e-5, rtol=5e-5)


def test_junk_row_never_attended():
    """Writing garbage into the last cache row must not change the output
    as long as cache_len < S-1 (the commit-scatter junk-row contract)."""
    w, n, g, h, d, s = 5, 3, 5, 4, 32, 256
    t = masks.t_in(w, n, g)
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randn(t, h, d).astype(np.float32))
    kn = jnp.asarray(rng.randn(t, h, d).astype(np.float32))
    vn = jnp.asarray(rng.randn(t, h, d).astype(np.float32))
    kc = rng.randn(s, h, d).astype(np.float32)
    vc = rng.randn(s, h, d).astype(np.float32)
    cl = jnp.asarray(100, dtype=jnp.int32)
    out1 = lookahead_attention(q, kn, vn, jnp.asarray(kc), jnp.asarray(vc),
                               cl, w, n, g)
    kc[-1] = 1e6
    vc[-1] = -1e6
    out2 = lookahead_attention(q, kn, vn, jnp.asarray(kc), jnp.asarray(vc),
                               cl, w, n, g)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_vmem_estimate_within_budget():
    est = vmem_estimate_bytes(t=120, d=32, s=768)
    assert est["fits_16MiB_vmem"]
    assert est["total"] > 0


def test_mxu_estimate_monotone_in_tile():
    lo = mxu_utilization_estimate(t=120, d=32, s=768, bq=4)
    hi = mxu_utilization_estimate(t=120, d=32, s=768, bq=8)
    assert hi["weighted"] >= lo["weighted"]
