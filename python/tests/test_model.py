"""L2 model tests: step-function consistency — the KV-cache/commit/decode
chain must be byte-identical (greedy) to full causal recomputation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import masks, model
from compile.config import MODELS, PAD_ID, VOCAB_SIZE

CFG = MODELS["draft"]  # smallest model: fast under CI
P = 32


@pytest.fixture(scope="module")
def ws():
    return [jnp.asarray(w) for w in model.init_weights(CFG, seed=1)]


@pytest.fixture(scope="module")
def fns():
    return {
        "prefill": jax.jit(model.make_prefill(CFG, P)),
        "dec1": jax.jit(model.make_decode_linear(CFG, 1)),
        "dec5": jax.jit(model.make_decode_linear(CFG, 5)),
        "la": jax.jit(model.make_decode_specialized(CFG, 5, 3, 5)),
        "la_pallas": jax.jit(
            model.make_decode_specialized(CFG, 5, 3, 5, attn_impl="pallas")),
        "gen64": jax.jit(model.make_decode_generic(CFG, 64)),
        "commit1": jax.jit(model.make_commit(CFG, 1)),
        "commit5": jax.jit(model.make_commit(CFG, 5)),
    }


def prompt_state(ws, fns, toks):
    pad = np.full(P, PAD_ID, np.int32)
    pad[:len(toks)] = toks
    logits, cache = fns["prefill"](*ws, jnp.asarray(pad),
                                   jnp.asarray(len(toks), jnp.int32))
    return cache, len(toks) - 1, int(toks[-1]), np.asarray(logits)


def ar_reference(ws, toks, steps):
    """Greedy continuation by full causal recomputation (no cache)."""
    seq = list(toks)
    out = []
    kvd = CFG.n_kv_heads * CFG.head_dim
    zcache = jnp.zeros((CFG.n_layers, 2, model.cache_rows(CFG), kvd),
                       jnp.float32)
    for _ in range(steps):
        t = len(seq)
        intra = jnp.asarray(np.tril(np.ones((t, t), bool)))
        logits, _ = model.forward_step(
            CFG, ws, zcache, jnp.asarray(0, jnp.int32),
            jnp.asarray(seq, jnp.int32), jnp.arange(t, dtype=jnp.int32), intra)
        nxt = int(jnp.argmax(logits[-1][:VOCAB_SIZE]))
        out.append(nxt)
        seq.append(nxt)
    return out


TOKS = np.random.RandomState(0).randint(0, 256, size=12).astype(np.int32)


def test_ar_chain_matches_full_recompute(ws, fns):
    cache, cache_len, cur, _ = prompt_state(ws, fns, TOKS)
    got = []
    idx0 = jnp.asarray([0] * 8, jnp.int32)
    for _ in range(6):
        logits, new_kv = fns["dec1"](*ws, cache,
                                     jnp.asarray(cache_len, jnp.int32),
                                     jnp.asarray([cur], jnp.int32))
        cur = int(jnp.argmax(logits[0][:VOCAB_SIZE]))
        cache = fns["commit1"](cache, new_kv, idx0,
                               jnp.asarray(cache_len, jnp.int32),
                               jnp.asarray(1, jnp.int32))
        cache_len += 1
        got.append(cur)
    assert got == ar_reference(ws, TOKS, 6)


def test_multi_token_decode_matches_ar(ws, fns):
    """decode_lin_5 over the AR continuation reproduces AR logits."""
    cache, cache_len, cur, _ = prompt_state(ws, fns, TOKS)
    ar = ar_reference(ws, TOKS, 5)
    chain = [cur] + ar[:4]
    logits, _ = fns["dec5"](*ws, cache, jnp.asarray(cache_len, jnp.int32),
                            jnp.asarray(chain, jnp.int32))
    got = [int(jnp.argmax(logits[i][:VOCAB_SIZE])) for i in range(5)]
    assert got == ar


def test_lookahead_verify_branch_matches_ar(ws, fns):
    cache, cache_len, cur, _ = prompt_state(ws, fns, TOKS)
    ar = ar_reference(ws, TOKS, 3)
    w, n, g = 5, 3, 5
    t = masks.t_in(w, n, g)
    rng = np.random.RandomState(3)
    la = rng.randint(0, 256, size=t).astype(np.int32)
    la[0] = cur
    base = masks.n_lookahead(w, n)
    la[base:base + 2] = ar[:2]  # candidate 0 = true continuation
    logits, _ = fns["la"](*ws, cache, jnp.asarray(cache_len, jnp.int32),
                          jnp.asarray(la))
    assert int(jnp.argmax(logits[0][:VOCAB_SIZE])) == ar[0]
    assert int(jnp.argmax(logits[base][:VOCAB_SIZE])) == ar[1]
    assert int(jnp.argmax(logits[base + 1][:VOCAB_SIZE])) == ar[2]


def test_pallas_and_jnp_decode_agree(ws, fns):
    cache, cache_len, cur, _ = prompt_state(ws, fns, TOKS)
    t = masks.t_in(5, 3, 5)
    la = np.random.RandomState(5).randint(0, 256, size=t).astype(np.int32)
    la[0] = cur
    a, _ = fns["la"](*ws, cache, jnp.asarray(cache_len, jnp.int32),
                     jnp.asarray(la))
    b, _ = fns["la_pallas"](*ws, cache, jnp.asarray(cache_len, jnp.int32),
                            jnp.asarray(la))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-4, rtol=2e-4)


def test_generic_decode_matches_specialized(ws, fns):
    """The mask-as-input executable with the (5,3,5) layout padded to 64
    produces the same logits on the live slots."""
    cache, cache_len, cur, _ = prompt_state(ws, fns, TOKS)
    w, n, g = 5, 3, 5
    t = masks.t_in(w, n, g)
    la = np.random.RandomState(7).randint(0, 256, size=t).astype(np.int32)
    la[0] = cur
    spec_logits, _ = fns["la"](*ws, cache, jnp.asarray(cache_len, jnp.int32),
                               jnp.asarray(la))
    tokens = np.full(64, PAD_ID, np.int32)
    tokens[:t] = la
    relpos = np.zeros(64, np.int32)
    relpos[:t] = masks.relative_positions(w, n, g)
    m = np.zeros((64, 64), np.uint8)
    m[:t, :t] = masks.intra_mask(w, n, g).astype(np.uint8)
    np.fill_diagonal(m, np.maximum(m.diagonal(), 1))  # pad rows see self only
    gen_logits, _ = fns["gen64"](*ws, cache, jnp.asarray(cache_len, jnp.int32),
                                 jnp.asarray(tokens), jnp.asarray(relpos),
                                 jnp.asarray(m))
    np.testing.assert_allclose(np.asarray(spec_logits),
                               np.asarray(gen_logits)[:t],
                               atol=2e-4, rtol=2e-4)


def test_commit_junk_row_isolated(ws, fns):
    """Slots beyond `count` land on the junk row and never affect decode."""
    cache, cache_len, cur, _ = prompt_state(ws, fns, TOKS)
    logits, new_kv = fns["dec5"](*ws, cache, jnp.asarray(cache_len, jnp.int32),
                                 jnp.asarray([cur, 1, 2, 3, 4], jnp.int32))
    idx = jnp.asarray([0, 1, 2, 3, 4, 0, 0, 0], jnp.int32)
    c1 = fns["commit5"](cache, new_kv, idx, jnp.asarray(cache_len, jnp.int32),
                        jnp.asarray(2, jnp.int32))
    c2 = np.asarray(c1)
    # rows cache_len..cache_len+1 written, junk row (S-1) clobbered, rest equal
    s = model.cache_rows(CFG)
    base = np.asarray(cache)
    changed = np.zeros(s, bool)
    changed[cache_len:cache_len + 2] = True
    changed[s - 1] = True
    np.testing.assert_array_equal(c2[:, :, ~changed, :], base[:, :, ~changed, :])
    # committed rows hold exactly the selected new_kv rows
    nk = np.asarray(new_kv)
    np.testing.assert_array_equal(c2[:, :, cache_len, :], nk[:, :, 0, :])
    np.testing.assert_array_equal(c2[:, :, cache_len + 1, :], nk[:, :, 1, :])


def test_weight_names_shapes_aligned():
    names, shapes = model.weight_names(CFG), model.weight_shapes(CFG)
    assert len(names) == len(shapes) == 1 + 9 * CFG.n_layers + 1
    ws_ = model.init_weights(CFG)
    assert [w.shape for w in ws_] == [tuple(s) for s in shapes]
