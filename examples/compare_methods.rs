//! Side-by-side comparison of every decoding engine on the same prompts:
//! autoregressive, Jacobi, speculative decoding (draft model), prompt
//! lookup, and Lookahead Decoding. All greedy engines are exact, so the
//! completions must be identical — only steps/latency differ.
//!
//!   cargo run --release --example compare_methods

use lookahead::bench::Table;
use lookahead::engine::autoregressive::AutoRegressive;
use lookahead::engine::jacobi::Jacobi;
use lookahead::engine::lookahead::Lookahead;
use lookahead::engine::prompt_lookup::PromptLookup;
use lookahead::engine::spec_decode::SpecDecode;
use lookahead::engine::{Decoder, GenParams};
use lookahead::runtime::{cpu_client, Manifest, ModelRuntime};
use lookahead::tokenizer::ByteTokenizer;
use lookahead::workload::Workloads;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts")?;
    let client = cpu_client()?;
    let rt = ModelRuntime::load(&client, &manifest, "tiny")?;
    let workloads = Workloads::load("artifacts")?;
    let prompts = workloads.take("code", 4)?;
    let tok = ByteTokenizer::new();
    let params = GenParams { max_new_tokens: 64, ..Default::default() };

    let mut engines: Vec<Box<dyn Decoder>> = vec![
        Box::new(AutoRegressive::new()),
        Box::new(Jacobi::new(8)),
        Box::new(PromptLookup::new(8, 1)),
        Box::new(SpecDecode::new(
            ModelRuntime::load(&client, &manifest, "draft")?, 4)),
        Box::new(Lookahead::with_wng(5, 3, 5)),
        Box::new(Lookahead::with_wng(15, 5, 15)),
    ];

    let mut table = Table::new(&["method", "steps", "S", "tok/s", "ms/req", "exact"]);
    let mut reference: Vec<String> = Vec::new();

    for engine in engines.iter_mut() {
        let mut steps = 0usize;
        let mut tokens = 0usize;
        let mut wall = 0.0f64;
        let mut outputs = Vec::new();
        for p in &prompts {
            let ids = tok.encode_with_bos(p);
            let out = engine.generate(&rt, &ids, &params)?;
            steps += out.stats.decode_steps;
            tokens += out.stats.generated_tokens;
            wall += out.stats.wall.as_secs_f64();
            outputs.push(out.text);
        }
        if reference.is_empty() {
            reference = outputs.clone();
        }
        let exact = outputs == reference;
        table.row(vec![
            engine.name(),
            steps.to_string(),
            format!("{:.2}", tokens as f64 / steps as f64),
            format!("{:.1}", tokens as f64 / wall),
            format!("{:.0}", wall * 1e3 / prompts.len() as f64),
            if exact { "yes".into() } else { "NO".into() },
        ]);
    }

    println!("\n{} prompts from the `code` suite, {} max tokens each, greedy:\n",
             prompts.len(), params.max_new_tokens);
    table.print();
    println!("\n'exact' = byte-identical to the autoregressive reference \
              (the paper's losslessness claim).");
    Ok(())
}
