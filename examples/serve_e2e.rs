//! END-TO-END VALIDATION DRIVER (DESIGN.md, EXPERIMENTS.md §E2E).
//!
//! Loads the real trained byte-level model, serves batched requests from
//! every workload suite through the full stack (scheduler -> worker ->
//! lookahead engine -> PJRT runtime -> AOT HLO artifacts), and reports
//! latency/throughput for lookahead vs the autoregressive baseline —
//! proving all three layers compose on a real small workload.
//!
//!   cargo run --release --example serve_e2e [-- --requests 6 --max-tokens 64]

use lookahead::bench::Table;
use lookahead::metrics::Histogram;
use lookahead::server::{Request, ServerConfig, ServerHandle};
use lookahead::util::cli::Args;
use lookahead::util::json::Json;
use lookahead::workload::{paper_dataset, Workloads, SUITE_NAMES};

fn run_method(method: &str, wng: (usize, usize, usize), n_req: usize,
              max_tokens: usize, workloads: &Workloads)
              -> anyhow::Result<(f64, Histogram, Histogram, usize)> {
    let h = ServerHandle::start(
        ServerConfig::builder().queue_depth(1024).wng(wng).build(),
    )?;
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    for suite in SUITE_NAMES {
        for p in workloads.take(suite, n_req)? {
            rxs.push(h.submit(Request::new(p).max_tokens(max_tokens).method(method))?);
        }
    }
    let mut lat = Histogram::new();
    let mut s_hist = Histogram::new();
    let mut tokens = 0usize;
    for rx in rxs {
        let r = rx.wait()?;
        anyhow::ensure!(r.error.is_none(), "{:?}", r.error);
        lat.record(r.wall_ms);
        s_hist.record(r.compression);
        tokens += r.tokens;
    }
    let wall = t0.elapsed().as_secs_f64();
    h.shutdown();
    Ok((wall, lat, s_hist, tokens))
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let n_req = args.usize_or("requests", 4);
    let max_tokens = args.usize_or("max-tokens", 64);
    let workloads = Workloads::load("artifacts")?;
    let total_reqs = n_req * SUITE_NAMES.len();

    println!("e2e serving validation: {} requests ({} per suite; suites map to {:?}), \
              {} max tokens, model=tiny\n",
             total_reqs, n_req,
             SUITE_NAMES.iter().map(|s| paper_dataset(s)).collect::<Vec<_>>(),
             max_tokens);

    let mut table = Table::new(&["method", "wall_s", "tok/s", "p50_ms", "p99_ms",
                                 "mean_S", "cpu_speedup", "A100_proj"]);
    let mut results = Vec::new();
    let mut base_tps = 0.0;
    for (method, wng) in [("autoregressive", (5, 3, 5)), ("lookahead", (15, 5, 15))] {
        let (wall, mut lat, s_hist, tokens) =
            run_method(method, wng, n_req, max_tokens, &workloads)?;
        let tps = tokens as f64 / wall;
        if base_tps == 0.0 {
            base_tps = tps;
        }
        // DESIGN.md §7: project the measured S onto a memory-bandwidth-bound
        // A100 at the paper's 7B scale (this CPU is compute-bound, so raw
        // CPU wall-clock understates the paper's regime).
        let t_in = (wng.0 + wng.2) * (wng.1 - 1);
        let proj = lookahead::analytic::projected_speedup(
            &lookahead::analytic::A100, 7e9, t_in.max(1), s_hist.mean());
        table.row(vec![
            method.into(),
            format!("{wall:.2}"),
            format!("{tps:.1}"),
            format!("{:.0}", lat.p50()),
            format!("{:.0}", lat.p99()),
            format!("{:.2}", s_hist.mean()),
            format!("{:.2}x", tps / base_tps),
            format!("{:.2}x", if method == "autoregressive" { 1.0 } else { proj }),
        ]);
        results.push(Json::obj(vec![
            ("method", Json::str(method)),
            ("wall_s", Json::num(wall)),
            ("tokens_per_sec", Json::num(tps)),
            ("p50_ms", Json::num(lat.p50())),
            ("p99_ms", Json::num(lat.p99())),
            ("mean_S", Json::num(s_hist.mean())),
        ]));
    }
    table.print();
    lookahead::bench::save_result("serve_e2e", Json::Arr(results));
    println!("\nresult appended to bench_results.json");
    Ok(())
}
