//! Multi-turn chat serving (the paper's MT-Bench analogue) through the full
//! serving front: scheduler, time-sliced worker pool, per-request latency
//! percentiles, and a live streaming turn at the end.
//!
//!   cargo run --release --example chat_serving

use lookahead::metrics::Histogram;
use lookahead::server::{Policy, Reply, Request, ServerConfig, ServerHandle};
use lookahead::workload::Workloads;

fn main() -> anyhow::Result<()> {
    let workloads = Workloads::load("artifacts")?;
    let prompts = workloads.take("chat", 12)?;

    let h = ServerHandle::start(
        ServerConfig::builder()
            .policy(Policy::ShortestFirst)
            .queue_depth(64)
            .share_ngrams(true) // multi-turn chat re-serves templates: warm pools
            .ngram_ttl_ms(Some(600_000)) // decay templates idle for 10 minutes
            .wng((15, 5, 15))
            .build(),
    )?;

    // Burst-submit the whole conversation set (SJF scheduler reorders).
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            h.submit(Request::new(p.clone()).max_tokens(48).seed(i as u64)).unwrap()
        })
        .collect();

    let mut lat = Histogram::new();
    let mut queue = Histogram::new();
    let mut ttft = Histogram::new();
    let mut s_hist = Histogram::new();
    let mut total_tokens = 0usize;
    let mut warm = 0usize;
    for rx in rxs {
        let r = rx.wait()?;
        assert!(r.error.is_none(), "{:?}", r.error);
        lat.record(r.wall_ms + r.queue_ms);
        queue.record(r.queue_ms);
        ttft.record(r.ttft_ms);
        s_hist.record(r.compression);
        total_tokens += r.tokens;
        warm += r.pool_warm as usize;
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("served {} chat requests in {:.2}s", prompts.len(), wall);
    println!("  throughput      : {:.1} tok/s aggregate", total_tokens as f64 / wall);
    println!("  e2e latency     : {}", lat.summary());
    println!("  queue wait      : {}", queue.summary());
    println!("  time-to-first   : {}", ttft.summary());
    println!("  step compression: mean {:.2} (chat is the paper's hardest suite)",
             s_hist.mean());
    println!("  warm-pool starts: {}/{} (cross-request shared n-gram cache)",
             warm, prompts.len());

    // one streaming turn: chunks print as each lookahead step commits
    println!("\nstreaming turn:");
    let rs = h.submit(Request::new(prompts[0].clone()).max_tokens(48).stream(true))?;
    loop {
        match rs.recv()? {
            Reply::Chunk(c) => print!("{}", c.delta),
            Reply::Done(r) => {
                println!("\n  [finish={} ttft={:.1}ms wall={:.1}ms tokens={}]",
                         r.finish, r.ttft_ms, r.wall_ms, r.tokens);
                break;
            }
        }
    }

    println!("\nserver metrics:\n{}", h.report());
    h.shutdown();
    Ok(())
}
