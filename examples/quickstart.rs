//! Quickstart: load the trained model from `artifacts/`, generate with
//! Lookahead Decoding (one-shot AND step-by-step via `DecodeSession`), and
//! print the step-compression statistics.
//!
//!   make artifacts && cargo run --release --example quickstart

use lookahead::engine::lookahead::Lookahead;
use lookahead::engine::{Decoder, GenParams, StepOutcome};
use lookahead::ngram::PoolHandle;
use lookahead::runtime::load_model;
use lookahead::tokenizer::{ByteTokenizer, Utf8StreamDecoder};

fn main() -> anyhow::Result<()> {
    // 1. Load the artifact manifest + model weights onto the PJRT CPU device.
    let (_manifest, rt) = load_model("artifacts", "tiny")?;

    // 2. Pick a decoding engine. (W, N, G) = (15, 5, 15) is the paper's
    //    recommended 7B-class configuration (Tab. 4).
    let mut engine = Lookahead::with_wng(15, 5, 15);

    // 3. Generate.
    let tok = ByteTokenizer::new();
    let prompt = "def cap_xy(x, y):\n    result = x";
    let ids = tok.encode_with_bos(prompt);
    let params = GenParams { max_new_tokens: 96, ..Default::default() };
    let out = engine.generate(&rt, &ids, &params)?;

    println!("prompt:\n{prompt}");
    println!("\ncompletion:\n{}", out.text);
    println!("\n--- stats ---");
    println!("engine            : {}", engine.name());
    println!("generated tokens  : {}", out.stats.generated_tokens);
    println!("decode steps      : {}", out.stats.decode_steps);
    println!("step compression S: {:.2}x  (1.0 = autoregressive)", out.stats.compression());
    println!("throughput        : {:.1} tok/s", out.stats.tokens_per_sec());
    println!("n-gram pool hits  : {} / {}", out.stats.pool_hits,
             out.stats.pool_hits + out.stats.pool_misses);

    // 4. The same generation, resumable: a DecodeSession commits a
    //    variable-length run of verified tokens per step — this is what the
    //    serving layer streams, time-slices, and cancels. Concatenated
    //    deltas are byte-identical to the one-shot output above.
    println!("\n--- per-step commits (DecodeSession) ---");
    let pool = PoolHandle::for_spec(engine.pool_spec());
    let mut sess = engine.begin(&rt, &ids, &params, pool)?;
    let mut dec = Utf8StreamDecoder::new();
    let mut step_no = 0usize;
    loop {
        match sess.step()? {
            StepOutcome::Committed { tokens } => {
                step_no += 1;
                println!("step {:>3}: +{} token(s) {:?}",
                         step_no, tokens.len(), dec.push(&tok.bytes(&tokens)));
            }
            StepOutcome::Finished { reason } => {
                println!("finished: {}", reason.as_str());
                break;
            }
        }
    }
    let (session_out, _pool) = sess.into_output();
    assert_eq!(session_out.tokens, out.tokens, "session must match one-shot");
    Ok(())
}
