//! Code-completion workload (the paper's HumanEval/ClassEval analogue):
//! lookahead decoding shines on repetitive code — watch S and the pool
//! hit-rate climb as the pool warms across a long class-completion.
//!
//!   cargo run --release --example code_completion

use lookahead::bench::Table;
use lookahead::engine::lookahead::{Lookahead, LookaheadConfig};
use lookahead::engine::{Decoder, GenParams};
use lookahead::runtime::load_model;
use lookahead::tokenizer::ByteTokenizer;
use lookahead::workload::Workloads;

fn main() -> anyhow::Result<()> {
    let (_, rt) = load_model("artifacts", "tiny")?;
    let workloads = Workloads::load("artifacts")?;
    let tok = ByteTokenizer::new();

    // ClassEval-style long completions (paper uses 2048 max tokens there;
    // scaled to the tiny model's cache).
    let params = GenParams { max_new_tokens: 256, ..Default::default() };

    let mut table = Table::new(&["suite", "prompt#", "tokens", "steps", "S",
                                 "pool-hit%", "tok/s"]);
    for suite in ["code", "class-code"] {
        for (i, prompt) in workloads.take(suite, 3)?.iter().enumerate() {
            let mut engine = Lookahead::with_wng(15, 5, 15);
            let ids = tok.encode_with_bos(prompt);
            let out = engine.generate(&rt, &ids, &params)?;
            let s = &out.stats;
            table.row(vec![
                suite.into(),
                i.to_string(),
                s.generated_tokens.to_string(),
                s.decode_steps.to_string(),
                format!("{:.2}", s.compression()),
                format!("{:.0}", 100.0 * s.pool_hits as f64
                        / (s.pool_hits + s.pool_misses).max(1) as f64),
                format!("{:.1}", s.tokens_per_sec()),
            ]);
        }
    }
    table.print();

    // Show one full completion.
    let prompt = &workloads.take("class-code", 1)?[0];
    let mut engine = Lookahead::new(LookaheadConfig::new(15, 5, 15));
    let out = engine.generate(&rt, &tok.encode_with_bos(prompt), &params)?;
    println!("\n=== sample class completion (S = {:.2}) ===", out.stats.compression());
    println!("{}{}", prompt, out.text);
    Ok(())
}
